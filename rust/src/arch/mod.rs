//! Architecture registry: named machine descriptions the whole stack is
//! parameterized over.
//!
//! The paper measures one Ampere part, but its stated purpose is feeding
//! performance models that track *architectures* — and the follow-on
//! literature (Hopper: arXiv:2402.13499, Blackwell: arXiv:2507.10789)
//! repeats the same methodology per generation.  An [`ArchSpec`] owns
//! everything a generation pins down:
//!
//! * clock / SM / warp geometry and the per-pipe (per instruction class)
//!   issue-occupancy and dependent-use latencies;
//! * the per-level memory hierarchy (sizes, line sizes, service
//!   latencies);
//! * the WMMA capability table — which Table III dtypes the generation's
//!   tensor cores support (Volta: fp16 only; Turing adds the integer
//!   configs; Ampere adds bf16/tf32/fp64);
//! * the SASS translation quirks ([`TranslationQuirks`]) the paper pins
//!   through dynamic traces.
//!
//! Three presets ship built in: [`ArchSpec::ampere`] is byte-identical
//! to the historical `AmpereConfig::a100()` (pinned by test — `repro
//! --arch ampere <cmd>` and plain `repro <cmd>` are the same run);
//! [`ArchSpec::volta`] and [`ArchSpec::turing`] are parameterized from
//! the paper's cited predecessor studies (Jia et al.'s Volta/Turing
//! dissections), calibrated the same way the Ampere defaults were.
//! Custom specs load from JSON (`repro --arch my_chip.json …`); the
//! schema is exactly [`ArchSpec::to_json`] and `repro arch show
//! <name> --json` prints a valid starting point.
//!
//! [`get`] resolves a `--arch` value (preset name, alias, or JSON
//! path); [`diff`] produces the field-level delta between two specs
//! (`repro arch diff volta ampere` shows, among others, the WMMA dtype
//! gap); `repro compare --arch a,b` runs whole campaigns per arch and
//! tabulates measured deltas (see [`crate::report::compare`]).

use crate::config::{
    AmpereConfig, FamilyTiming, NextGenConfig, Pipe, PipeTiming, TranslationQuirks, WgmmaFlavor,
    ALL_PIPES,
};
use crate::tensor::{WmmaDtype, ALL_DTYPES};
use crate::util::json::{parse, to_string_pretty, Value};

/// Built-in preset names, in generation order.
pub const BUILTIN: [&str; 5] = ["volta", "turing", "ampere", "hopper", "blackwell"];

/// The next-gen family keys, in [`NextGenConfig`] field order (the JSON
/// schema, `flatten`, the latency model and the compare table all use
/// these same strings).
pub const NEXTGEN_FAMILIES: [&str; 4] = ["cp_async", "tma", "wgmma", "dsmem"];

/// A named, serializable machine description.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchSpec {
    /// Human-readable description (chip, product, provenance).
    pub display: String,
    /// The full machine config every layer threads.
    pub config: AmpereConfig,
}

/// Stable JSON/CLI key for a pipe.
fn pipe_key(p: Pipe) -> &'static str {
    match p {
        Pipe::Int => "int",
        Pipe::Fma => "fma",
        Pipe::Half => "half",
        Pipe::Fp64 => "fp64",
        Pipe::Sfu => "sfu",
        Pipe::Lsu => "lsu",
        Pipe::Tensor => "tensor",
        Pipe::Uniform => "uniform",
        Pipe::Control => "control",
        Pipe::Special => "special",
    }
}

fn pipe_mut(cfg: &mut AmpereConfig, p: Pipe) -> &mut PipeTiming {
    match p {
        Pipe::Int => &mut cfg.int_pipe,
        Pipe::Fma => &mut cfg.fma_pipe,
        Pipe::Half => &mut cfg.half_pipe,
        Pipe::Fp64 => &mut cfg.fp64_pipe,
        Pipe::Sfu => &mut cfg.sfu_pipe,
        Pipe::Lsu => &mut cfg.lsu_pipe,
        Pipe::Tensor => &mut cfg.tensor_pipe,
        Pipe::Uniform => &mut cfg.uniform_pipe,
        Pipe::Control => &mut cfg.control_pipe,
        Pipe::Special => &mut cfg.special_pipe,
    }
}

fn dtype_from_key(key: &str) -> Option<WmmaDtype> {
    ALL_DTYPES.into_iter().find(|d| d.key() == key)
}

impl ArchSpec {
    pub fn name(&self) -> &str {
        &self.config.arch_name
    }

    /// Ampere GA100 — byte-identical to the historical
    /// [`AmpereConfig::a100`] defaults (pinned by test), so the arch
    /// registry changes nothing about existing runs.
    pub fn ampere() -> ArchSpec {
        ArchSpec {
            display: "Ampere GA100 (A100-SXM4, the paper's testbed)".to_string(),
            config: AmpereConfig::a100(),
        }
    }

    /// Volta GV100 (V100-class), parameterized from the predecessor
    /// literature the paper cites (Jia et al., "Dissecting the NVIDIA
    /// Volta GPU Architecture via Microbenchmarking") and calibrated
    /// under the same measurement protocol as the Ampere defaults.
    pub fn volta() -> ArchSpec {
        let mut c = AmpereConfig::a100();
        c.arch_name = "volta".to_string();
        c.sm_count = 80;
        c.tensor.cores_per_sm = 8;
        c.tensor.clock_hz = 1.530e9;
        // First-generation tensor cores: fp16 inputs only.
        c.wmma_dtypes = vec![WmmaDtype::F16F16, WmmaDtype::F16F32];
        // Memory hierarchy (V100: 128 KiB unified L1, 6 MiB L2).
        c.memory.l1_bytes = 128 * 1024;
        c.memory.l2_bytes = 6 * 1024 * 1024;
        c.memory.shared_bytes = 96 * 1024;
        c.memory.l1_hit_latency = 28;
        c.memory.l2_hit_latency = 193;
        c.memory.dram_latency = 400;
        c.memory.shared_load_latency = 19;
        c.memory.shared_store_latency = 15;
        // Per-SM bandwidth ceilings (Jia et al.'s V100 sustained-rate
        // measurements, scaled per SM): half Ampere's L1 path.
        c.memory.l1_bytes_per_cycle = 64;
        c.memory.l2_bytes_per_cycle = 48;
        c.memory.dram_bytes_per_cycle = 24;
        // Packed-half path is a cycle slower than Ampere's.
        c.half_pipe = PipeTiming::new(2, 4);
        // §V-A's dependent-add pipe borrow and Insight 3's mov-folding
        // are Ampere-toolchain observations.
        c.quirks.dep_add_fma_alternation = false;
        c.quirks.neg_abs_mov_folding = false;
        // Pre-Ampere: none of the async instruction families exist.
        c.nextgen = NextGenConfig::none();
        ArchSpec { display: "Volta GV100 (Tesla V100-SXM2)".to_string(), config: c }
    }

    /// Turing TU104 (Tesla T4-class), parameterized from Jia et al.,
    /// "Dissecting the NVIDIA Turing T4 GPU via Microbenchmarking",
    /// calibrated like the other presets.
    pub fn turing() -> ArchSpec {
        let mut c = AmpereConfig::a100();
        c.arch_name = "turing".to_string();
        c.sm_count = 40;
        c.tensor.cores_per_sm = 8;
        c.tensor.clock_hz = 1.590e9;
        // Second generation adds the integer configs; bf16/tf32/fp64
        // arrive with Ampere.
        c.wmma_dtypes = vec![
            WmmaDtype::F16F16,
            WmmaDtype::F16F32,
            WmmaDtype::U8S32,
            WmmaDtype::U4S32,
        ];
        c.memory.l1_bytes = 64 * 1024;
        c.memory.l2_bytes = 4 * 1024 * 1024;
        c.memory.shared_bytes = 64 * 1024;
        c.memory.l1_hit_latency = 32;
        c.memory.l2_hit_latency = 188;
        c.memory.dram_latency = 350;
        c.memory.shared_load_latency = 19;
        c.memory.shared_store_latency = 15;
        // T4 is a bandwidth-lean part: 64 B/cycle L1, GDDR6 behind a
        // narrower per-SM slice.
        c.memory.l1_bytes_per_cycle = 64;
        c.memory.l2_bytes_per_cycle = 32;
        c.memory.dram_bytes_per_cycle = 16;
        // TU104 keeps only 2 FP64 units per SM (1/32 rate): the fp64
        // issue port is occupied far longer per warp instruction.
        c.fp64_pipe = PipeTiming::new(16, 6);
        c.quirks.dep_add_fma_alternation = false;
        // Pre-Ampere: no async-copy family (LDGSTS arrives with sm_80).
        c.nextgen = NextGenConfig::none();
        ArchSpec { display: "Turing TU104 (Tesla T4)".to_string(), config: c }
    }

    /// Hopper GH100 (H100-SXM5), parameterized from the successor study
    /// that repeats the paper's methodology on sm_90 (Luo et al.,
    /// "Benchmarking and Dissecting the Nvidia Hopper GPU Architecture",
    /// arXiv:2402.13499) and calibrated under the same protocol.
    pub fn hopper() -> ArchSpec {
        let mut c = AmpereConfig::a100();
        c.arch_name = "hopper".to_string();
        c.sm_count = 132;
        c.tensor.clock_hz = 1.830e9;
        // Memory hierarchy (H100: 256 KiB L1, 50 MiB L2, 228 KiB SMEM).
        c.memory.l1_bytes = 256 * 1024;
        c.memory.l2_bytes = 50 * 1024 * 1024;
        c.memory.shared_bytes = 228 * 1024;
        c.memory.l2_hit_latency = 273;
        c.memory.dram_latency = 650;
        c.memory.shared_load_latency = 29;
        c.memory.shared_store_latency = 23;
        // Hopper widens L2 and HBM3 per-SM bandwidth (Luo et al. §IV).
        c.memory.l2_bytes_per_cycle = 96;
        c.memory.dram_bytes_per_cycle = 48;
        // sm_90's full async surface: faster LDGSTS than Ampere, the
        // TMA bulk-tensor engine, warpgroup MMA (HGMMA at warpgroup
        // granularity) and DSMEM cluster access.
        c.nextgen = NextGenConfig {
            cp_async: Some(FamilyTiming::new(2, 48)),
            tma: Some(FamilyTiming::new(4, 190)),
            wgmma: Some(FamilyTiming::new(16, 32)),
            dsmem: Some(FamilyTiming::new(2, 49)),
            wgmma_flavor: WgmmaFlavor::Hgmma,
        };
        ArchSpec { display: "Hopper GH100 (H100-SXM5)".to_string(), config: c }
    }

    /// Blackwell GB100 (B200-class), parameterized from the sm_100
    /// instruction-latency study (Jarmusch et al., arXiv:2507.10789),
    /// calibrated like the other presets.
    pub fn blackwell() -> ArchSpec {
        let mut c = AmpereConfig::a100();
        c.arch_name = "blackwell".to_string();
        c.sm_count = 148;
        c.tensor.clock_hz = 1.665e9;
        // B200: 256 KiB L1, 126 MiB L2 (one die's partition view),
        // 228 KiB SMEM carry-over from Hopper.
        c.memory.l1_bytes = 256 * 1024;
        c.memory.l2_bytes = 126 * 1024 * 1024;
        c.memory.shared_bytes = 228 * 1024;
        c.memory.l2_hit_latency = 286;
        c.memory.dram_latency = 600;
        c.memory.shared_load_latency = 30;
        c.memory.shared_store_latency = 24;
        // HBM3e doubles Ampere's per-SM DRAM rate; L2 matches the L1
        // line rate (Jarmusch et al.'s sustained-bandwidth tables).
        c.memory.l2_bytes_per_cycle = 128;
        c.memory.dram_bytes_per_cycle = 64;
        // The async families carry forward with tightened latencies;
        // warpgroup MMA retires through the tcgen05 tensor-memory path.
        c.nextgen = NextGenConfig {
            cp_async: Some(FamilyTiming::new(2, 44)),
            tma: Some(FamilyTiming::new(4, 170)),
            wgmma: Some(FamilyTiming::new(16, 28)),
            dsmem: Some(FamilyTiming::new(2, 42)),
            wgmma_flavor: WgmmaFlavor::Tcgen05,
        };
        ArchSpec { display: "Blackwell GB100 (B200)".to_string(), config: c }
    }

    // ---- serialization (the custom-spec JSON schema) -----------------

    pub fn to_json(&self) -> Value {
        let c = &self.config;
        let mut pipes = Value::obj();
        for p in ALL_PIPES {
            let t = c.pipe(p);
            pipes = pipes.set(
                pipe_key(p),
                Value::obj()
                    .set("occupancy", t.occupancy)
                    .set("latency", t.latency)
                    .set("ports", t.ports),
            );
        }
        let m = &c.memory;
        Value::obj()
            .set("name", c.arch_name.as_str())
            .set("display", self.display.as_str())
            .set("sm_count", c.sm_count as u64)
            .set("clock_read_occupancy", c.clock_read_occupancy)
            .set("cold_start_extra", c.cold_start_extra)
            .set("depbar_stall", c.depbar_stall)
            .set("issue_width", c.issue_width)
            .set(
                "control_flow",
                Value::obj()
                    .set("branch_taken_extra", c.branch_taken_extra)
                    .set("predicated_skip_occupancy", c.predicated_skip_occupancy),
            )
            .set("pipes", pipes)
            .set(
                "memory",
                Value::obj()
                    .set("l1_bytes", m.l1_bytes)
                    .set("l1_line", m.l1_line)
                    .set("l1_assoc", m.l1_assoc)
                    .set("l2_bytes", m.l2_bytes)
                    .set("l2_line", m.l2_line)
                    .set("l2_assoc", m.l2_assoc)
                    .set("l1_hit_latency", m.l1_hit_latency)
                    .set("l2_hit_latency", m.l2_hit_latency)
                    .set("dram_latency", m.dram_latency)
                    .set("shared_load_latency", m.shared_load_latency)
                    .set("shared_store_latency", m.shared_store_latency)
                    .set("shared_bytes", m.shared_bytes)
                    .set("sector_bytes", m.sector_bytes)
                    .set("l1_bytes_per_cycle", m.l1_bytes_per_cycle)
                    .set("l2_bytes_per_cycle", m.l2_bytes_per_cycle)
                    .set("dram_bytes_per_cycle", m.dram_bytes_per_cycle)
                    .set("shared_banks", m.shared_banks)
                    .set("shared_bank_bytes", m.shared_bank_bytes),
            )
            .set(
                "tensor",
                Value::obj()
                    .set("cores_per_sm", c.tensor.cores_per_sm as u64)
                    .set("clock_hz", c.tensor.clock_hz)
                    .set("startup_cycles", c.tensor.startup_cycles),
            )
            .set(
                "wmma",
                Value::Arr(c.wmma_dtypes.iter().map(|d| Value::from(d.key())).collect()),
            )
            .set(
                "quirks",
                Value::obj()
                    .set("dep_add_fma_alternation", c.quirks.dep_add_fma_alternation)
                    .set("neg_abs_mov_folding", c.quirks.neg_abs_mov_folding)
                    .set("clock32_depbar", c.quirks.clock32_depbar),
            )
            .set("nextgen", {
                let mut ng = Value::obj();
                for key in NEXTGEN_FAMILIES {
                    ng = ng.set(
                        key,
                        match c.nextgen.family(key) {
                            Some(t) => Value::obj()
                                .set("occupancy", t.occupancy)
                                .set("latency", t.latency),
                            None => Value::Null,
                        },
                    );
                }
                ng.set("wgmma_flavor", c.nextgen.wgmma_flavor.key())
            })
    }

    pub fn to_json_string(&self) -> String {
        to_string_pretty(&self.to_json())
    }

    pub fn from_json(v: &Value) -> Result<ArchSpec, String> {
        let need_u64 = |v: &Value, key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("arch json: missing numeric field {key:?}"))
        };
        let need_bool = |v: &Value, key: &str| -> Result<bool, String> {
            v.get(key)
                .and_then(Value::as_bool)
                .ok_or_else(|| format!("arch json: missing boolean field {key:?}"))
        };
        let name = v
            .get("name")
            .and_then(Value::as_str)
            .ok_or("arch json: missing string field \"name\"")?
            .to_string();
        let display = v
            .get("display")
            .and_then(Value::as_str)
            .unwrap_or(name.as_str())
            .to_string();

        // Every section below is required: a partial spec silently
        // inheriting Ampere values would be a calibration foot-gun.
        let mut c = AmpereConfig::a100();
        c.arch_name = name;
        c.sm_count = need_u64(v, "sm_count")? as u32;
        c.clock_read_occupancy = need_u64(v, "clock_read_occupancy")?;
        c.cold_start_extra = need_u64(v, "cold_start_extra")?;
        c.depbar_stall = need_u64(v, "depbar_stall")?;
        // Throughput-scheduler knobs: optional with the neutral default
        // of 1, so specs written before the multi-warp engine still load
        // (1 is not an Ampere-specific value — every preset uses it).
        c.issue_width = v.get("issue_width").and_then(Value::as_u64).unwrap_or(1);
        // Branch/predication timing: optional with the zero-impact
        // defaults, so specs written before the control-flow extension
        // still load (0/1 are not Ampere-specific — every preset uses
        // them).
        c.branch_taken_extra = 0;
        c.predicated_skip_occupancy = 1;
        if let Some(cf) = v.get("control_flow") {
            if let Some(x) = cf.get("branch_taken_extra").and_then(Value::as_u64) {
                c.branch_taken_extra = x;
            }
            if let Some(x) = cf.get("predicated_skip_occupancy").and_then(Value::as_u64) {
                c.predicated_skip_occupancy = x;
            }
        }

        let pipes = v.get("pipes").ok_or("arch json: missing \"pipes\" object")?;
        for p in ALL_PIPES {
            let key = pipe_key(p);
            let t = pipes
                .get(key)
                .ok_or_else(|| format!("arch json: pipes missing {key:?}"))?;
            *pipe_mut(&mut c, p) = PipeTiming::with_ports(
                need_u64(t, "occupancy")?,
                need_u64(t, "latency")?,
                t.get("ports").and_then(Value::as_u64).unwrap_or(1),
            );
        }

        let m = v.get("memory").ok_or("arch json: missing \"memory\" object")?;
        c.memory.l1_bytes = need_u64(m, "l1_bytes")? as usize;
        c.memory.l1_line = need_u64(m, "l1_line")? as usize;
        c.memory.l1_assoc = need_u64(m, "l1_assoc")? as usize;
        c.memory.l2_bytes = need_u64(m, "l2_bytes")? as usize;
        c.memory.l2_line = need_u64(m, "l2_line")? as usize;
        c.memory.l2_assoc = need_u64(m, "l2_assoc")? as usize;
        c.memory.l1_hit_latency = need_u64(m, "l1_hit_latency")?;
        c.memory.l2_hit_latency = need_u64(m, "l2_hit_latency")?;
        c.memory.dram_latency = need_u64(m, "dram_latency")?;
        c.memory.shared_load_latency = need_u64(m, "shared_load_latency")?;
        c.memory.shared_store_latency = need_u64(m, "shared_store_latency")?;
        c.memory.shared_bytes = need_u64(m, "shared_bytes")? as usize;
        // Bandwidth / sector / bank fields load *leniently* with the
        // A100-calibrated defaults, so spec files (and models) written
        // before the MLP engine still load — same pattern as
        // `issue_width` and the control-flow section.  They never enter
        // the single-warp latency path, so a legacy spec's measured
        // tables are unchanged by the defaults.
        let lenient = |key: &str, dflt: u64| m.get(key).and_then(Value::as_u64).unwrap_or(dflt);
        let d = crate::config::MemoryConfig::default();
        c.memory.sector_bytes = lenient("sector_bytes", d.sector_bytes);
        c.memory.l1_bytes_per_cycle = lenient("l1_bytes_per_cycle", d.l1_bytes_per_cycle);
        c.memory.l2_bytes_per_cycle = lenient("l2_bytes_per_cycle", d.l2_bytes_per_cycle);
        c.memory.dram_bytes_per_cycle = lenient("dram_bytes_per_cycle", d.dram_bytes_per_cycle);
        c.memory.shared_banks = lenient("shared_banks", d.shared_banks);
        c.memory.shared_bank_bytes = lenient("shared_bank_bytes", d.shared_bank_bytes);

        let t = v.get("tensor").ok_or("arch json: missing \"tensor\" object")?;
        c.tensor.cores_per_sm = need_u64(t, "cores_per_sm")? as u32;
        c.tensor.clock_hz = t
            .get("clock_hz")
            .and_then(Value::as_f64)
            .ok_or("arch json: missing numeric field \"clock_hz\"")?;
        c.tensor.startup_cycles = need_u64(t, "startup_cycles")?;

        let wmma = v
            .get("wmma")
            .and_then(Value::as_arr)
            .ok_or("arch json: missing \"wmma\" array")?;
        c.wmma_dtypes = wmma
            .iter()
            .map(|d| {
                d.as_str()
                    .and_then(dtype_from_key)
                    .ok_or_else(|| {
                        format!(
                            "arch json: unknown wmma dtype {d:?} (valid: {})",
                            ALL_DTYPES.map(|x| x.key()).join(", ")
                        )
                    })
            })
            .collect::<Result<Vec<_>, String>>()?;

        let q = v.get("quirks").ok_or("arch json: missing \"quirks\" object")?;
        c.quirks = TranslationQuirks {
            dep_add_fma_alternation: need_bool(q, "dep_add_fma_alternation")?,
            neg_abs_mov_folding: need_bool(q, "neg_abs_mov_folding")?,
            clock32_depbar: need_bool(q, "clock32_depbar")?,
        };

        // Next-gen families load *leniently*: a spec written before the
        // family table existed describes a machine without the families
        // (absent ≠ inherit-Ampere — an arch must opt in explicitly).
        c.nextgen = crate::config::NextGenConfig::none();
        if let Some(ng) = v.get("nextgen") {
            for key in NEXTGEN_FAMILIES {
                match ng.get(key) {
                    None | Some(Value::Null) => {}
                    Some(t) => {
                        *c.nextgen.family_mut(key).unwrap() = Some(FamilyTiming::new(
                            need_u64(t, "occupancy")
                                .map_err(|e| format!("{e} (in nextgen.{key})"))?,
                            need_u64(t, "latency")
                                .map_err(|e| format!("{e} (in nextgen.{key})"))?,
                        ));
                    }
                }
            }
            if let Some(f) = ng.get("wgmma_flavor").and_then(Value::as_str) {
                c.nextgen.wgmma_flavor = WgmmaFlavor::from_key(f).ok_or_else(|| {
                    format!("arch json: unknown wgmma_flavor {f:?} (valid: hgmma, tcgen05)")
                })?;
            }
        }

        Ok(ArchSpec { display, config: c })
    }

    pub fn from_json_str(s: &str) -> Result<ArchSpec, String> {
        Self::from_json(&parse(s).map_err(|e| format!("arch json: {e}"))?)
    }

    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json_string()).map_err(|e| format!("write {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<ArchSpec, String> {
        let s = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::from_json_str(&s).map_err(|e| format!("{path}: {e}"))
    }

    // ---- flattening (the `arch show` / `arch diff` surface) ----------

    /// Flatten the spec into a deterministic `(field, value)` listing —
    /// the same fixed schema for every spec, so [`diff`] can align
    /// specs field by field.
    pub fn flatten(&self) -> Vec<(String, String)> {
        let c = &self.config;
        let mut out: Vec<(String, String)> = vec![
            ("name".into(), c.arch_name.clone()),
            ("display".into(), self.display.clone()),
            ("sm_count".into(), c.sm_count.to_string()),
            ("clock_read_occupancy".into(), c.clock_read_occupancy.to_string()),
            ("cold_start_extra".into(), c.cold_start_extra.to_string()),
            ("depbar_stall".into(), c.depbar_stall.to_string()),
            ("issue_width".into(), c.issue_width.to_string()),
            (
                "control_flow.branch_taken_extra".into(),
                c.branch_taken_extra.to_string(),
            ),
            (
                "control_flow.predicated_skip_occupancy".into(),
                c.predicated_skip_occupancy.to_string(),
            ),
        ];
        for p in ALL_PIPES {
            let t = c.pipe(p);
            out.push((format!("pipe.{}.occupancy", pipe_key(p)), t.occupancy.to_string()));
            out.push((format!("pipe.{}.latency", pipe_key(p)), t.latency.to_string()));
            out.push((format!("pipe.{}.ports", pipe_key(p)), t.ports.to_string()));
        }
        let m = &c.memory;
        for (k, v) in [
            ("memory.l1_bytes", m.l1_bytes as u64),
            ("memory.l1_line", m.l1_line as u64),
            ("memory.l1_assoc", m.l1_assoc as u64),
            ("memory.l2_bytes", m.l2_bytes as u64),
            ("memory.l2_line", m.l2_line as u64),
            ("memory.l2_assoc", m.l2_assoc as u64),
            ("memory.l1_hit_latency", m.l1_hit_latency),
            ("memory.l2_hit_latency", m.l2_hit_latency),
            ("memory.dram_latency", m.dram_latency),
            ("memory.shared_load_latency", m.shared_load_latency),
            ("memory.shared_store_latency", m.shared_store_latency),
            ("memory.shared_bytes", m.shared_bytes as u64),
            ("memory.sector_bytes", m.sector_bytes),
            ("memory.l1_bytes_per_cycle", m.l1_bytes_per_cycle),
            ("memory.l2_bytes_per_cycle", m.l2_bytes_per_cycle),
            ("memory.dram_bytes_per_cycle", m.dram_bytes_per_cycle),
            ("memory.shared_banks", m.shared_banks),
            ("memory.shared_bank_bytes", m.shared_bank_bytes),
        ] {
            out.push((k.into(), v.to_string()));
        }
        out.push(("tensor.cores_per_sm".into(), c.tensor.cores_per_sm.to_string()));
        out.push(("tensor.clock_hz".into(), format!("{:.0}", c.tensor.clock_hz)));
        out.push(("tensor.startup_cycles".into(), c.tensor.startup_cycles.to_string()));
        for d in ALL_DTYPES {
            out.push((
                format!("wmma.{}", d.key()),
                if c.supports_wmma(d) { "yes" } else { "no" }.to_string(),
            ));
        }
        out.push((
            "quirks.dep_add_fma_alternation".into(),
            c.quirks.dep_add_fma_alternation.to_string(),
        ));
        out.push((
            "quirks.neg_abs_mov_folding".into(),
            c.quirks.neg_abs_mov_folding.to_string(),
        ));
        out.push(("quirks.clock32_depbar".into(), c.quirks.clock32_depbar.to_string()));
        for key in NEXTGEN_FAMILIES {
            let (occ, lat) = match c.nextgen.family(key) {
                Some(t) => (t.occupancy.to_string(), t.latency.to_string()),
                None => ("-".to_string(), "-".to_string()),
            };
            out.push((format!("nextgen.{key}.occupancy"), occ));
            out.push((format!("nextgen.{key}.latency"), lat));
        }
        out.push(("nextgen.wgmma_flavor".into(), c.nextgen.wgmma_flavor.key().to_string()));
        out
    }

    /// `arch show`: the flattened spec as a printed table.
    pub fn show_table(&self) -> String {
        crate::report::render_table(
            &format!("arch {} — {}", self.name(), self.display),
            &["field", "value"],
            &self
                .flatten()
                .into_iter()
                .map(|(k, v)| vec![k, v])
                .collect::<Vec<_>>(),
        )
    }
}

/// All built-in presets, in [`BUILTIN`] order.
pub fn list() -> Vec<ArchSpec> {
    vec![
        ArchSpec::volta(),
        ArchSpec::turing(),
        ArchSpec::ampere(),
        ArchSpec::hopper(),
        ArchSpec::blackwell(),
    ]
}

/// Canonical preset name for any accepted alias: product and chip
/// names (`a100`/`v100`/`t4`/…) and the pre-registry `a100-sim` model
/// tag all fold to their generation.  Unknown names pass through
/// unchanged.  The single alias table — [`get`], the serving router's
/// per-request `"arch"` field and the model's arch check all resolve
/// through it.
pub fn normalize(name: &str) -> &str {
    match name {
        "a100" | "a100-sim" | "ga100" => "ampere",
        "v100" | "gv100" => "volta",
        "t4" | "tu104" => "turing",
        "h100" | "gh100" => "hopper",
        "b200" | "gb100" | "gb200" => "blackwell",
        other => other,
    }
}

/// Resolve a `--arch` value: a built-in preset name (with the product
/// aliases the literature uses, via [`normalize`]), or a path to a
/// custom-spec JSON file.
pub fn get(name: &str) -> Result<ArchSpec, String> {
    match normalize(name) {
        "ampere" => Ok(ArchSpec::ampere()),
        "volta" => Ok(ArchSpec::volta()),
        "turing" => Ok(ArchSpec::turing()),
        "hopper" => Ok(ArchSpec::hopper()),
        "blackwell" => Ok(ArchSpec::blackwell()),
        other => {
            if other.ends_with(".json") || std::path::Path::new(other).is_file() {
                ArchSpec::load(other)
            } else {
                Err(format!(
                    "unknown architecture {other:?}; built-ins: {} (or pass a \
                     custom-spec JSON path — `repro arch show ampere --json` \
                     prints the schema)",
                    BUILTIN.join(", ")
                ))
            }
        }
    }
}

/// One differing field between two specs.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffRow {
    pub field: String,
    pub a: String,
    pub b: String,
}

/// Field-level delta between two specs (fields equal in both are
/// omitted).  Both flatten to the same fixed schema, so rows align by
/// construction.
pub fn diff(a: &ArchSpec, b: &ArchSpec) -> Vec<DiffRow> {
    a.flatten()
        .into_iter()
        .zip(b.flatten())
        .filter(|((_, va), (_, vb))| va != vb)
        .map(|((field, va), (_, vb))| DiffRow { field, a: va, b: vb })
        .collect()
}

/// `arch diff`: the delta as a printed table.
pub fn diff_table(a: &ArchSpec, b: &ArchSpec) -> String {
    let rows = diff(a, b);
    if rows.is_empty() {
        return format!("\narch {} and {} are identical\n", a.name(), b.name());
    }
    crate::report::render_table(
        &format!("arch diff — {} vs {}", a.name(), b.name()),
        &["field", a.name(), b.name()],
        &rows
            .into_iter()
            .map(|r| vec![r.field, r.a, r.b])
            .collect::<Vec<_>>(),
    )
}

/// `arch diff --json`.
pub fn diff_json(a: &ArchSpec, b: &ArchSpec) -> Value {
    Value::obj()
        .set("a", a.name())
        .set("b", b.name())
        .set(
            "differences",
            Value::Arr(
                diff(a, b)
                    .into_iter()
                    .map(|r| {
                        Value::obj()
                            .set("field", r.field)
                            .set("a", r.a)
                            .set("b", r.b)
                    })
                    .collect(),
            ),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ampere_preset_is_the_legacy_default_config() {
        // The byte-identity anchor: `--arch ampere` must change nothing.
        assert_eq!(ArchSpec::ampere().config, AmpereConfig::a100());
        assert_eq!(
            ArchSpec::ampere().config.clone().into_small(),
            AmpereConfig::small()
        );
    }

    #[test]
    fn presets_resolve_by_name_and_alias() {
        for (alias, want) in [
            ("ampere", "ampere"),
            ("a100", "ampere"),
            ("a100-sim", "ampere"),
            ("volta", "volta"),
            ("v100", "volta"),
            ("turing", "turing"),
            ("t4", "turing"),
            ("hopper", "hopper"),
            ("h100", "hopper"),
            ("gh100", "hopper"),
            ("blackwell", "blackwell"),
            ("b200", "blackwell"),
            ("gb200", "blackwell"),
        ] {
            assert_eq!(get(alias).unwrap().name(), want, "{alias}");
        }
        let err = get("kepler").unwrap_err();
        assert!(err.contains("volta, turing, ampere, hopper, blackwell"), "{err}");
        assert_eq!(list().len(), BUILTIN.len());
    }

    #[test]
    fn nextgen_capability_tables_follow_the_generations() {
        use crate::config::WgmmaFlavor;
        // Pre-Ampere: nothing.  Ampere: cp.async only.  Hopper adds
        // TMA + wgmma + DSMEM; Blackwell keeps them with tightened
        // latencies and the tcgen05 lowering.
        for name in ["volta", "turing"] {
            let ng = get(name).unwrap().config.nextgen;
            for key in NEXTGEN_FAMILIES {
                assert!(ng.family(key).is_none(), "{name} must lack {key}");
            }
        }
        let amp = ArchSpec::ampere().config.nextgen;
        assert_eq!(amp.cp_async.map(|t| (t.occupancy, t.latency)), Some((2, 52)));
        assert!(amp.tma.is_none() && amp.wgmma.is_none() && amp.dsmem.is_none());

        let hop = ArchSpec::hopper().config.nextgen;
        for key in NEXTGEN_FAMILIES {
            assert!(hop.family(key).is_some(), "hopper must support {key}");
        }
        assert_eq!(hop.wgmma_flavor, WgmmaFlavor::Hgmma);

        let bw = ArchSpec::blackwell().config.nextgen;
        assert_eq!(bw.wgmma_flavor, WgmmaFlavor::Tcgen05);
        for key in NEXTGEN_FAMILIES {
            let (h, b) = (hop.family(key).unwrap(), bw.family(key).unwrap());
            assert!(
                b.latency <= h.latency,
                "{key}: blackwell {} must not regress hopper {}",
                b.latency,
                h.latency
            );
        }
    }

    #[test]
    fn nextgen_section_round_trips_and_loads_leniently() {
        // Dropping the whole section is NOT an error (pre-family specs
        // stay loadable) — it means "no families", not "inherit Ampere".
        let mut v = ArchSpec::ampere().to_json();
        if let Value::Obj(m) = &mut v {
            m.remove("nextgen");
        }
        let loaded = ArchSpec::from_json_str(&to_string_pretty(&v)).unwrap();
        assert!(loaded.config.nextgen.cp_async.is_none());

        // A malformed family entry IS an error naming the path.
        let raw = ArchSpec::hopper()
            .to_json_string()
            .replace("\"latency\": 190", "\"latency\": \"fast\"");
        let err = ArchSpec::from_json_str(&raw).unwrap_err();
        assert!(err.contains("nextgen.tma"), "{err}");

        // And the flattened diff surfaces the family gap.
        let rows = diff(&ArchSpec::ampere(), &ArchSpec::hopper());
        let find = |field: &str| {
            rows.iter()
                .find(|r| r.field == field)
                .unwrap_or_else(|| panic!("missing {field}: {rows:?}"))
        };
        assert_eq!(find("nextgen.tma.latency").a, "-");
        assert_eq!(find("nextgen.tma.latency").b, "190");
        assert_eq!(find("nextgen.cp_async.latency").a, "52");
        let bw = diff(&ArchSpec::hopper(), &ArchSpec::blackwell());
        assert!(bw.iter().any(|r| r.field == "nextgen.wgmma_flavor" && r.b == "tcgen05"));
    }

    #[test]
    fn json_round_trip_is_identity_for_every_preset() {
        for spec in list() {
            let s = spec.to_json_string();
            let back = ArchSpec::from_json_str(&s)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name()));
            assert_eq!(back, spec, "{}", spec.name());
        }
    }

    #[test]
    fn json_rejects_partial_specs() {
        assert!(ArchSpec::from_json_str("{}").is_err());
        assert!(ArchSpec::from_json_str("not json").is_err());
        // Dropping a required section is an error, not silent Ampere
        // inheritance.
        let mut v = ArchSpec::turing().to_json();
        if let Value::Obj(m) = &mut v {
            m.remove("memory");
        }
        let err = ArchSpec::from_json_str(&to_string_pretty(&v)).unwrap_err();
        assert!(err.contains("memory"), "{err}");
        // And an unknown wmma dtype names the valid keys.
        let mut v = ArchSpec::turing().to_json();
        if let Value::Obj(m) = &mut v {
            m.insert("wmma".into(), Value::Arr(vec![Value::from("f8_f8")]));
        }
        let err = ArchSpec::from_json_str(&to_string_pretty(&v)).unwrap_err();
        assert!(err.contains("f16_f16"), "{err}");
    }

    #[test]
    fn diff_shows_the_wmma_dtype_gap() {
        let rows = diff(&ArchSpec::volta(), &ArchSpec::ampere());
        let find = |field: &str| {
            rows.iter()
                .find(|r| r.field == field)
                .unwrap_or_else(|| panic!("missing {field}: {rows:?}"))
        };
        // The generation gap: bf16/tf32/fp64/int WMMA are Ampere-only
        // relative to Volta.
        for d in ["bf16_f32", "tf32_f32", "f64_f64", "u8_s32", "u4_s32"] {
            let r = find(&format!("wmma.{d}"));
            assert_eq!((r.a.as_str(), r.b.as_str()), ("no", "yes"), "{d}");
        }
        // Both support fp16, so it is not a difference.
        assert!(rows.iter().all(|r| r.field != "wmma.f16_f16"));
        // Geometry differences surface too.
        assert_eq!(find("sm_count").b, "108");
        assert_eq!(find("memory.dram_latency").a, "400");
        let rendered = diff_table(&ArchSpec::volta(), &ArchSpec::ampere());
        assert!(rendered.contains("wmma.bf16_f32"), "{rendered}");

        // Self-diff is empty.
        assert!(diff(&ArchSpec::ampere(), &ArchSpec::ampere()).is_empty());
        assert!(diff_table(&ArchSpec::ampere(), &ArchSpec::ampere()).contains("identical"));
    }

    #[test]
    fn throughput_knobs_round_trip_and_default_leniently() {
        // Non-default port widths / issue width survive the JSON trip.
        let mut spec = ArchSpec::ampere();
        spec.config.arch_name = "wide".into();
        spec.config.int_pipe.ports = 2;
        spec.config.issue_width = 2;
        let back = ArchSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back, spec);

        // A spec written before the multi-warp engine (no issue_width
        // field) still loads, with the neutral default of 1.
        let mut v = ArchSpec::turing().to_json();
        if let Value::Obj(m) = &mut v {
            m.remove("issue_width");
        }
        let loaded = ArchSpec::from_json_str(&to_string_pretty(&v)).unwrap();
        assert_eq!(loaded.config.issue_width, 1);
        assert!(loaded.flatten().iter().any(|(k, v)| k == "pipe.fp64.ports" && v == "1"));
    }

    #[test]
    fn bandwidth_fields_round_trip_and_default_leniently() {
        // Non-default bandwidth/bank values survive the JSON trip.
        let mut spec = ArchSpec::ampere();
        spec.config.arch_name = "fat-pipe".into();
        spec.config.memory.l2_bytes_per_cycle = 256;
        spec.config.memory.shared_banks = 16;
        let back = ArchSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back, spec);
        assert!(back
            .flatten()
            .iter()
            .any(|(k, v)| k == "memory.l2_bytes_per_cycle" && v == "256"));

        // A spec written before the MLP engine — its memory object has
        // none of the bandwidth fields — still loads, with the
        // A100-calibrated defaults.
        let mut v = ArchSpec::turing().to_json();
        if let Some(m) = v.get("memory").cloned() {
            if let Value::Obj(mut mem) = m {
                for k in [
                    "sector_bytes",
                    "l1_bytes_per_cycle",
                    "l2_bytes_per_cycle",
                    "dram_bytes_per_cycle",
                    "shared_banks",
                    "shared_bank_bytes",
                ] {
                    mem.remove(k);
                }
                if let Value::Obj(top) = &mut v {
                    top.insert("memory".into(), Value::Obj(mem));
                }
            }
        }
        let loaded = ArchSpec::from_json_str(&to_string_pretty(&v)).unwrap();
        let d = crate::config::MemoryConfig::default();
        assert_eq!(loaded.config.memory.sector_bytes, d.sector_bytes);
        assert_eq!(loaded.config.memory.l1_bytes_per_cycle, d.l1_bytes_per_cycle);
        assert_eq!(loaded.config.memory.shared_banks, d.shared_banks);
        // The strict fields are still strict.
        assert_eq!(loaded.config.memory.l2_bytes, 4 * 1024 * 1024);

        // And the flattened diff surfaces per-generation bandwidth.
        let rows = diff(&ArchSpec::ampere(), &ArchSpec::hopper());
        let r = rows
            .iter()
            .find(|r| r.field == "memory.dram_bytes_per_cycle")
            .expect("bandwidth must flatten");
        assert_eq!((r.a.as_str(), r.b.as_str()), ("32", "48"));
    }

    #[test]
    fn control_flow_timing_round_trips_and_defaults_leniently() {
        // Non-default branch/predication timing survives the JSON trip.
        let mut spec = ArchSpec::ampere();
        spec.config.arch_name = "branchy".into();
        spec.config.branch_taken_extra = 3;
        spec.config.predicated_skip_occupancy = 2;
        let back = ArchSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back, spec);
        assert!(back
            .flatten()
            .iter()
            .any(|(k, v)| k == "control_flow.branch_taken_extra" && v == "3"));

        // A spec written before the control-flow extension (no section)
        // still loads, with the zero-impact defaults.
        let mut v = ArchSpec::turing().to_json();
        if let Value::Obj(m) = &mut v {
            m.remove("control_flow");
        }
        let loaded = ArchSpec::from_json_str(&to_string_pretty(&v)).unwrap();
        assert_eq!(loaded.config.branch_taken_extra, 0);
        assert_eq!(loaded.config.predicated_skip_occupancy, 1);
    }

    #[test]
    fn custom_spec_loads_from_a_file() {
        let mut spec = ArchSpec::turing();
        spec.config.arch_name = "my-turing".into();
        spec.config.sm_count = 46;
        let path = std::env::temp_dir().join("arch_custom_spec.json");
        let path = path.to_str().unwrap();
        spec.save(path).unwrap();
        let loaded = get(path).unwrap();
        assert_eq!(loaded, spec);
        assert_eq!(loaded.name(), "my-turing");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn show_table_lists_every_field() {
        let s = ArchSpec::turing().show_table();
        for needle in ["sm_count", "pipe.fp64.occupancy", "memory.l2_bytes", "wmma.u4_s32"] {
            assert!(s.contains(needle), "{needle} missing from:\n{s}");
        }
    }
}
