//! # ampere-ubench
//!
//! Reproduction of *"Demystifying the Nvidia Ampere Architecture through
//! Microbenchmarking and Instruction-level Analysis"* (Abdelkhalik et al.,
//! CS.AR 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper measures, on a physical A100: per-instruction clock-cycle
//! latencies for the PTX ISA and their SASS translations (Tables I, II, V),
//! memory access latencies via pointer chasing (Table IV), and tensor-core
//! WMMA latency/throughput per data type (Table III).  We have no GPU, so
//! per the substitution rule every hardware dependence is replaced by a
//! from-scratch software substrate (see `DESIGN.md` §Substitutions):
//!
//! * [`arch`] — the architecture registry: named, serializable machine
//!   descriptions ([`ArchSpec`]) with built-in Volta/Turing/Ampere
//!   presets and custom-spec JSON loading; every layer below is
//!   parameterized by the spec's machine config (`repro --arch …`,
//!   `repro arch list/show/diff`, `repro compare --arch a,b`).
//! * [`ptx`] — PTX ISA front-end: lexer, parser, AST, kernel builder.
//! * [`sass`] — SASS ISA: opcodes, pipes, the per-opcode timing table.
//! * [`translate`] — the context-sensitive PTX→SASS translating assembler
//!   (the observable behaviour of `ptxas` that the paper characterises).
//! * [`sim`] — the cycle-level Ampere SM model: in-order issue, per-pipe
//!   occupancy/latency, scoreboard, clock registers, pipe-drain
//!   semantics — plus the deterministic multi-warp throughput scheduler
//!   ([`sim::throughput`]): N resident warps round-robin over per-pipe
//!   issue ports — now also charging per-level memory bandwidth
//!   (sector-granular) and shared-memory bank conflicts — achieved IPC
//!   vs. warp count, 1-warp replay byte-identical to the latency path
//!   (`repro throughput`).
//! * [`memory`] — global/L2/L1/shared memory hierarchy with `.cv/.cg/.ca`
//!   cache-operator semantics (Table IV's latencies *emerge* from hits).
//! * [`tensor`] — tensor-core model: WMMA shape→SASS decomposition, MOVM
//!   layout rules, latency & throughput (Table III).
//! * [`trace`] — dynamic SASS trace capture (the PPT-GPU tool analogue).
//! * [`microbench`] — the paper's actual contribution: the microbenchmark
//!   generators + measurement protocol, including the latency-vs-MLP
//!   saturation sweep ([`microbench::mlp`]) that turns Table IV point
//!   latencies into per-arch bandwidth curves (`repro mlp`).
//! * [`isa`] — the next-gen ISA subsystem: registry + two-sided (issue /
//!   completion) measurement campaign for the post-Ampere instruction
//!   families (`cp.async`, TMA, `wgmma`, DSMEM) across the Hopper and
//!   Blackwell presets.
//! * [`engine`] — the campaign execution engine: content-addressed
//!   kernel cache (each distinct PTX source parses/translates once),
//!   simulator pool with cheap reset-on-return, and a fine-grained work
//!   queue that schedules every table *row* across all cores with
//!   deterministic result ordering.
//! * [`harness`] — campaign orchestrator running the full evaluation on
//!   the engine; [`report`] renders the paper's tables.
//! * [`oracle`] — the latency oracle, the layer that *consumes* the
//!   measurements the way the paper says they are used (performance-
//!   modeling simulators à la PPT-GPU): campaign results distilled into
//!   a serializable analytical [`LatencyModel`](oracle::LatencyModel),
//!   dependence-aware static prediction of kernel cycles, and a
//!   JSON-line TCP server with request batching, an LRU prediction
//!   cache and live-simulation fallback (`repro serve`).
//! * [`fuzz`] — the adversarial correctness layer: a seeded grammar
//!   fuzzer over the PTX surface, a three-path differential harness
//!   (pooled engine vs fresh simulator vs static predictor) with
//!   divergence classification and seed-minimized reproducers, and the
//!   golden conformance suite pinning Tables I–V + Fig. 4 against
//!   `tests/golden/` snapshots (`repro fuzz` / `repro conformance`).
//! * [`runtime`] — PJRT client loading the AOT JAX/Pallas artifacts; the
//!   WMMA numerics oracle on the request path (python is build-time only).
//!
//! Rendered documentation lives in `docs/`: `docs/ARCHITECTURE.md` (the
//! subsystem map and table/figure index), `docs/USAGE.md` (the CLI
//! reference, compiled into `repro help` verbatim) and `docs/WIRE.md`
//! (the serving wire protocol, both framings).

// Clippy runs blocking in CI (`cargo clippy --release -- -D warnings`).
// The allows below are deliberate structural choices, not unfixed
// findings: the serving/batching layers pass `(id, parsed-request)`
// tuples and cache `(source, Arc<value>)` pairs whose types are clearer
// inline than behind one-use type aliases; simulator entry points
// (`Simulator::do_load`, `TraceRecorder::record_issue`) thread the full
// machine state as parameters by design; and the campaign's demux enum
// intentionally carries whole row results of differing sizes.
#![allow(clippy::type_complexity)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::large_enum_variant)]

pub mod arch;
pub mod config;
pub mod engine;
pub mod fuzz;
pub mod harness;
pub mod isa;
pub mod memory;
pub mod microbench;
pub mod oracle;
pub mod ptx;
pub mod report;
pub mod runtime;
pub mod sass;
pub mod sim;
pub mod tensor;
pub mod trace;
pub mod translate;
pub mod util;

pub use arch::ArchSpec;
pub use config::AmpereConfig;
pub use engine::Engine;
pub use oracle::{LatencyModel, LatencyOracle};
