//! Dynamic-trace tooling (the PPT-GPU *Tracing Tool* analogue, S7 in
//! DESIGN.md).
//!
//! The recorder itself lives in [`crate::sass::trace`] (the simulator
//! writes it); this module adds the *analysis* side the paper's
//! methodology uses: verifying a microbenchmark executed exactly the
//! SASS the experimenter intended, and diffing traces across variants
//! (e.g. Fig. 4's 32- vs 64-bit clock kernels).

pub use crate::sass::trace::{TraceEntry, TraceRecorder};

/// A trace assertion: what the experimenter expects to see between the
/// two clock reads (paper §IV: "we tweak the PTX microbenchmark by trial
/// and error to give us the correct SASS results").
#[derive(Debug, Clone)]
pub struct TraceExpectation {
    /// Mnemonics that must appear, in order (gaps allowed).
    pub ordered: Vec<&'static str>,
    /// Mnemonics that must NOT appear anywhere in the window.
    pub forbidden: Vec<&'static str>,
}

impl TraceExpectation {
    /// Check the expectation over the measured window (between the first
    /// and last clock-read entries).
    pub fn check(&self, trace: &TraceRecorder) -> Result<(), String> {
        let entries = trace.entries();
        let clock_positions: Vec<usize> = entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.mnemonic.starts_with("CS2R") || e.mnemonic == "S2R")
            .map(|(i, _)| i)
            .collect();
        let (lo, hi) = match (clock_positions.first(), clock_positions.last()) {
            (Some(a), Some(b)) if a < b => (*a, *b),
            _ => (0, entries.len()),
        };
        let window = &entries[lo..hi];

        let mut next = 0usize;
        for e in window {
            if next < self.ordered.len() && e.mnemonic == self.ordered[next] {
                next += 1;
            }
            if self.forbidden.contains(&e.mnemonic) {
                return Err(format!("forbidden {} in measured window", e.mnemonic));
            }
        }
        if next < self.ordered.len() {
            return Err(format!(
                "missing {} (saw {:?})",
                self.ordered[next],
                window.iter().map(|e| e.mnemonic).collect::<Vec<_>>()
            ));
        }
        Ok(())
    }
}

/// Per-PTX-instruction dynamic instruction counts — the histogram view
/// of a trace.
pub fn dynamic_histogram(trace: &TraceRecorder) -> Vec<(&'static str, u64)> {
    let mut counts: std::collections::HashMap<&'static str, u64> = Default::default();
    for e in trace.entries() {
        *counts.entry(e.mnemonic).or_default() += 1;
    }
    let mut v: Vec<_> = counts.into_iter().collect();
    v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_trace() -> TraceRecorder {
        let mut t = TraceRecorder::new();
        for (i, m) in ["CS2R", "IADD", "IADD", "IADD", "CS2R"].iter().enumerate() {
            t.record(i as u32, m, i as u64 * 2, i as u64 * 2 + 4);
        }
        t
    }

    #[test]
    fn expectation_passes_on_intended_sass() {
        let exp = TraceExpectation {
            ordered: vec!["IADD", "IADD", "IADD"],
            forbidden: vec!["DEPBAR"],
        };
        exp.check(&demo_trace()).unwrap();
    }

    #[test]
    fn expectation_rejects_missing_and_forbidden() {
        let exp = TraceExpectation { ordered: vec!["FFMA"], forbidden: vec![] };
        assert!(exp.check(&demo_trace()).is_err());

        let exp = TraceExpectation { ordered: vec![], forbidden: vec!["IADD"] };
        assert!(exp.check(&demo_trace()).is_err());
    }

    #[test]
    fn histogram_sorts_by_count() {
        let h = dynamic_histogram(&demo_trace());
        assert_eq!(h[0], ("IADD", 3));
        assert_eq!(h[1], ("CS2R", 2));
    }
}
