//! Machine description of the simulated SM.
//!
//! All architectural parameters in one place, so the ablation benches can
//! vary them and the [`crate::arch`] registry can instantiate whole
//! presets (Volta / Turing / Ampere, or a custom JSON spec).  Defaults
//! are A100-class (whitepaper values where public, calibrated to the
//! paper's measurements otherwise); the struct keeps its historical
//! `AmpereConfig` name — it is the machine-config type every layer
//! already threads — but since the arch registry landed it describes
//! *whichever* architecture it was built for (`arch_name`).


/// Execution-pipe timing: `occupancy` is the issue-port reservation in
/// cycles for one warp-instruction (32 threads / lane count), `latency`
/// is issue-to-result-forwarding in cycles.
///
/// Calibration note (see DESIGN.md §Substitutions): under the paper's
/// measurement protocol — `CPI = floor((Δclock − 2) / n)` with n = 3 and
/// clock reads that serialize with pipe drain — these values reproduce
/// Tables I and II exactly:
///
/// ```text
/// add.u32 indep: i1@+2 i2@+4 i3@+6, drain = max(2+5, 4+4, 6+4) = 10
///                Δ = 10 → (10−2)/3 = 2   (paper: 2)
/// add.u32 dep:   i1@+2 i2@+7 i3@+11, drain = 15
///                Δ = 15 → (15−2)/3 = 4   (paper: 4)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipeTiming {
    /// Cycles the pipe's issue port is busy per warp instruction
    /// (= 32 / lanes-per-SM-partition).
    pub occupancy: u64,
    /// Issue-to-result latency (dependent-use distance).
    pub latency: u64,
    /// Parallel issue ports of this pipe per SM sub-partition.  The
    /// single-warp latency simulator never queues two instructions on
    /// one pipe closer than `occupancy`, so one port is always enough
    /// there; the multi-warp throughput scheduler
    /// ([`crate::sim::throughput`]) arbitrates N resident warps over
    /// these ports, so the pipe's peak issue rate is
    /// `ports / occupancy` warp-instructions per cycle (e.g. Turing's
    /// 1-port, occupancy-16 fp64 pipe is the paper-lineage "1/32 rate").
    pub ports: u64,
}

impl PipeTiming {
    pub const fn new(occupancy: u64, latency: u64) -> Self {
        Self { occupancy, latency, ports: 1 }
    }

    /// A pipe with more than one issue port (custom specs; every
    /// built-in preset models the one port per sub-partition the
    /// dissection literature reports).
    pub const fn with_ports(occupancy: u64, latency: u64, ports: u64) -> Self {
        Self { occupancy, latency, ports }
    }
}

/// Functional-unit pipes of one SM sub-partition (GA100).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Pipe {
    /// INT32 ALU (16 lanes/partition → occupancy 2).
    Int,
    /// FP32 FMA pipe — also executes integer IMAD/FFMA-mapped ops.
    Fma,
    /// FP16x2 / packed-half path (HADD2/HMUL2/HFMA2).
    Half,
    /// FP64 units (8 lanes/partition → occupancy 4).
    Fp64,
    /// SFU / MUFU transcendental unit (4 lanes → occupancy 8).
    Sfu,
    /// Load/store unit.
    Lsu,
    /// Tensor core.
    Tensor,
    /// Uniform datapath (U* opcodes: scalar per warp).
    Uniform,
    /// Branch/control (BRA, EXIT, BAR).
    Control,
    /// Special-register reads (CS2R/S2R) and NOP — the "issue" pipe.
    Special,
}

pub const ALL_PIPES: [Pipe; 10] = [
    Pipe::Int,
    Pipe::Fma,
    Pipe::Half,
    Pipe::Fp64,
    Pipe::Sfu,
    Pipe::Lsu,
    Pipe::Tensor,
    Pipe::Uniform,
    Pipe::Control,
    Pipe::Special,
];

/// Architecture-specific `ptxas` translation behaviours the paper pins
/// through dynamic traces.  The Ampere defaults are the observations of
/// §V-A / Insight 3 / Fig. 4; predecessor presets switch off what the
/// literature only reports for Ampere.  Threaded from the machine config
/// into [`crate::translate::Translator`] by the engine's kernel cache,
/// so two engines over different architectures can never share (or
/// cross-contaminate) translations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TranslationQuirks {
    /// §V-A: a dependent `add.u32` chain alternates `IADD3` /
    /// `IMAD.IADD` (the compiler borrows the FP pipe while the INT pipe
    /// is busy).  Off: the chain stays `IADD3` on the INT pipe.
    pub dep_add_fma_alternation: bool,
    /// Insight 3: `neg.f32`/`abs.f32` fold into `IMAD.MOV.U32` when
    /// their input was initialised by `mov`.  Off: always `FADD`.
    pub neg_abs_mov_folding: bool,
    /// Fig. 4a: the second 32-bit clock read of a measured pair is
    /// guarded by a scheduling barrier (`DEPBAR` + `S2R`).  Off: 32-bit
    /// clock reads stay barrier-free `CS2R.32`.
    pub clock32_depbar: bool,
}

impl Default for TranslationQuirks {
    fn default() -> Self {
        Self {
            dep_add_fma_alternation: true,
            neg_abs_mov_folding: true,
            clock32_depbar: true,
        }
    }
}

/// Issue/latency parameters of one post-Ampere instruction family
/// (async copy, TMA, warpgroup MMA, distributed shared memory).
/// `occupancy` is the issue-port reservation charged at issue,
/// `latency` is issue-to-completion — for the asynchronous families
/// that completion is retired through a commit/wait group, not a
/// register scoreboard (see `sim::core`'s pending-group channels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FamilyTiming {
    pub occupancy: u64,
    pub latency: u64,
}

impl FamilyTiming {
    pub const fn new(occupancy: u64, latency: u64) -> Self {
        Self { occupancy, latency }
    }
}

/// Which SASS flavour a generation's warpgroup MMA lowers to: Hopper
/// issues `HGMMA` from the warpgroup, Blackwell retargets the tensor
/// memory path (`TCGEN05.MMA`, Jarmusch et al. §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WgmmaFlavor {
    Hgmma,
    Tcgen05,
}

impl WgmmaFlavor {
    /// Stable JSON/CLI key.
    pub fn key(self) -> &'static str {
        match self {
            WgmmaFlavor::Hgmma => "hgmma",
            WgmmaFlavor::Tcgen05 => "tcgen05",
        }
    }

    pub fn from_key(s: &str) -> Option<Self> {
        match s {
            "hgmma" => Some(WgmmaFlavor::Hgmma),
            "tcgen05" => Some(WgmmaFlavor::Tcgen05),
            _ => None,
        }
    }
}

/// Post-Ampere instruction-family capability table: `None` means the
/// architecture lacks the family and the translator rejects its PTX.
///
/// The default is the *Ampere* capability set — `cp.async` (LDGSTS)
/// arrived with sm_80, everything else is Hopper+ — so
/// `AmpereConfig::default()` keeps describing the paper's testbed
/// exactly.  Cited parameters: Luo et al. (arXiv 2402.13499) for
/// Hopper, Jarmusch et al. (arXiv 2507.10789) for Blackwell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NextGenConfig {
    /// `cp.async` global→shared copy (SASS LDGSTS): latency is
    /// issue-to-group-completion for an L1-resident line.
    pub cp_async: Option<FamilyTiming>,
    /// TMA bulk tensor load (SASS UTMALDG): descriptor-driven block
    /// copy, completion through the same async-group channel.
    pub tma: Option<FamilyTiming>,
    /// Warpgroup MMA (HGMMA / TCGEN05.MMA): charged on the tensor pipe
    /// at warpgroup granularity, accumulate is asynchronous.
    pub wgmma: Option<FamilyTiming>,
    /// Distributed shared memory — `ld/st.shared::cluster` (SASS
    /// LDS.CLUSTER): synchronous, remote-SM latency.
    pub dsmem: Option<FamilyTiming>,
    /// SASS lowering of the wgmma family on this generation.
    pub wgmma_flavor: WgmmaFlavor,
}

impl Default for NextGenConfig {
    fn default() -> Self {
        // Ampere: LDGSTS exists (§V-era sm_80 ISA); the copy completes
        // at L1-hit latency + shared-store service on the LSU pipe.
        Self {
            cp_async: Some(FamilyTiming::new(2, 52)),
            tma: None,
            wgmma: None,
            dsmem: None,
            wgmma_flavor: WgmmaFlavor::Hgmma,
        }
    }
}

impl NextGenConfig {
    /// Look a family up by its stable string key (the JSON schema, the
    /// flattened diff, the latency model and the compare table all key
    /// on these).
    pub fn family(&self, key: &str) -> Option<FamilyTiming> {
        match key {
            "cp_async" => self.cp_async,
            "tma" => self.tma,
            "wgmma" => self.wgmma,
            "dsmem" => self.dsmem,
            _ => None,
        }
    }

    /// Mutable slot for a family key (`None` for unknown keys).
    pub fn family_mut(&mut self, key: &str) -> Option<&mut Option<FamilyTiming>> {
        match key {
            "cp_async" => Some(&mut self.cp_async),
            "tma" => Some(&mut self.tma),
            "wgmma" => Some(&mut self.wgmma),
            "dsmem" => Some(&mut self.dsmem),
            _ => None,
        }
    }

    /// Pre-sm_80 generations: no next-gen family at all.
    pub const fn none() -> Self {
        Self {
            cp_async: None,
            tma: None,
            wgmma: None,
            dsmem: None,
            wgmma_flavor: WgmmaFlavor::Hgmma,
        }
    }
}

/// Memory-hierarchy geometry, service latencies and — since the MLP
/// engine — per-level bandwidth ceilings.
///
/// Latencies are *service* times at each level; the measured Table IV
/// numbers emerge from the pointer-chase microbenchmark traversing the
/// cache model (hit/miss decided by the actual cache state, not scripted).
/// The bandwidth fields never enter the single-warp latency path: they
/// bound how fast the multi-warp throughput scheduler
/// ([`crate::sim::throughput`]) and the MLP saturation sweep
/// ([`crate::microbench::mlp`]) can *overlap* accesses, so Table IV
/// stays byte-identical whatever values they take.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// L1 data cache per SM (A100: 192 KiB unified; data partition modeled).
    pub l1_bytes: usize,
    pub l1_line: usize,
    pub l1_assoc: usize,
    /// L2 total (A100: 40 MiB).
    pub l2_bytes: usize,
    pub l2_line: usize,
    pub l2_assoc: usize,
    /// Issue-to-data latency for an L1 hit (paper: 33).
    pub l1_hit_latency: u64,
    /// Issue-to-data latency for an L2 hit (paper: 200).
    pub l2_hit_latency: u64,
    /// Issue-to-data latency for DRAM (paper: 290, caching bypassed).
    pub dram_latency: u64,
    /// Shared-memory load latency (paper: 23).
    pub shared_load_latency: u64,
    /// Shared-memory store completion (paper: 19).
    pub shared_store_latency: u64,
    /// Shared memory size per SM (A100: up to 164 KiB).
    pub shared_bytes: usize,
    /// Memory-transaction sector size in bytes (the unit one lane's
    /// access occupies a level's return path; NVIDIA: 32 B sectors on
    /// every generation this registry models).
    pub sector_bytes: u64,
    /// L1 return bandwidth per SM, bytes/cycle (A100: a full 128 B line
    /// per cycle).
    pub l1_bytes_per_cycle: u64,
    /// L2 bandwidth per SM slice, bytes/cycle.
    pub l2_bytes_per_cycle: u64,
    /// DRAM bandwidth per SM, bytes/cycle.
    pub dram_bytes_per_cycle: u64,
    /// Shared-memory banks per SM (32 on every generation modeled).
    pub shared_banks: u64,
    /// Bytes one bank serves per cycle (4 B words).
    pub shared_bank_bytes: u64,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        Self {
            l1_bytes: 128 * 1024,
            l1_line: 128,
            l1_assoc: 4,
            l2_bytes: 40 * 1024 * 1024,
            l2_line: 128,
            l2_assoc: 16,
            l1_hit_latency: 33,
            l2_hit_latency: 200,
            dram_latency: 290,
            shared_load_latency: 23,
            shared_store_latency: 19,
            shared_bytes: 164 * 1024,
            sector_bytes: 32,
            l1_bytes_per_cycle: 128,
            l2_bytes_per_cycle: 64,
            dram_bytes_per_cycle: 32,
            shared_banks: 32,
            shared_bank_bytes: 4,
        }
    }
}

/// Tensor-core unit parameters (Table III).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorConfig {
    /// TCs per SM (Ampere: 4).
    pub cores_per_sm: u32,
    /// SM boost clock, Hz (A100: 1410 MHz) — used for GB/s conversion.
    pub clock_hz: f64,
    /// Pipeline startup cycles before the first MMA result streams out.
    pub startup_cycles: u64,
}

impl Default for TensorConfig {
    fn default() -> Self {
        Self { cores_per_sm: 4, clock_hz: 1.410e9, startup_cycles: 32 }
    }
}

/// Full machine description.
#[derive(Debug, Clone, PartialEq)]
pub struct AmpereConfig {
    /// Architecture identity (`ampere` / `volta` / `turing` / a custom
    /// spec's name).  Campaigns, extracted models and the serving layer
    /// key on it so cross-architecture numbers never silently mix.
    pub arch_name: String,
    /// SM count (A100: 108 enabled of 128; paper's intro says "124" for
    /// the full GA100 die — we default to the A100 product's 108).
    pub sm_count: u32,
    /// Clock-read instruction (CS2R) issue-port occupancy.  Two
    /// back-to-back reads differ by exactly this — the paper's measured
    /// clock overhead of 2 cycles.
    pub clock_read_occupancy: u64,
    /// Extra result latency for the first instruction executed on a cold
    /// pipe within a kernel (the paper's "first launch overhead";
    /// Table I's 5→3→2→2 amortisation reproduces from this).
    pub cold_start_extra: u64,
    /// Stall cycles of the scheduling barrier ptxas inserts between
    /// 32-bit clock reads (Fig. 4a: CPI 13 vs 2) — SASS `DEPBAR`.
    pub depbar_stall: u64,
    /// Warp-scheduler issue slots per cycle per SM sub-partition.  The
    /// single-warp simulator's 1-cycle dispatch skew is this field's
    /// value of 1; the multi-warp throughput scheduler enforces it
    /// across *all* resident warps, so total issue rate can never
    /// exceed `issue_width` instructions per cycle however many warps
    /// are resident.
    pub issue_width: u64,
    /// Extra pipeline-refill cycles a *taken* branch charges before the
    /// next instruction may issue (a fall-through branch pays only the
    /// control pipe's occupancy).  0 on every built-in preset — the
    /// single-warp protocol never resolves a refill penalty distinct
    /// from BRA's own occupancy — but per-arch specs can calibrate it.
    pub branch_taken_extra: u64,
    /// Issue-slot cycles a predicated-off (`@%p` false) instruction
    /// still occupies.  A squashed instruction is charged at issue
    /// only: no result latency, no register write, no pipe reservation
    /// beyond this slot.
    pub predicated_skip_occupancy: u64,
    /// Per-pipe steady-state timings.
    pub int_pipe: PipeTiming,
    pub fma_pipe: PipeTiming,
    pub half_pipe: PipeTiming,
    pub fp64_pipe: PipeTiming,
    pub sfu_pipe: PipeTiming,
    pub lsu_pipe: PipeTiming,
    pub tensor_pipe: PipeTiming,
    pub uniform_pipe: PipeTiming,
    pub control_pipe: PipeTiming,
    pub special_pipe: PipeTiming,
    pub memory: MemoryConfig,
    pub tensor: TensorConfig,
    /// Architecture-specific translation behaviours (see
    /// [`TranslationQuirks`]).
    pub quirks: TranslationQuirks,
    /// WMMA capability table: which Table III dtypes this generation's
    /// tensor cores support, in `ALL_DTYPES` order (Volta: fp16 only;
    /// Turing adds the integer configs; Ampere adds bf16/tf32/fp64).
    pub wmma_dtypes: Vec<crate::tensor::WmmaDtype>,
    /// Post-Ampere instruction-family capability/timing table (see
    /// [`NextGenConfig`]).  Threaded into the translator alongside
    /// `quirks` so unavailable families are rejected at compile time.
    pub nextgen: NextGenConfig,
}

impl Default for AmpereConfig {
    fn default() -> Self {
        Self {
            arch_name: "ampere".to_string(),
            sm_count: 108,
            clock_read_occupancy: 2,
            cold_start_extra: 1,
            depbar_stall: 31,
            issue_width: 1,
            branch_taken_extra: 0,
            predicated_skip_occupancy: 1,
            // (occupancy, latency); occupancy = 32 / lanes-per-partition.
            int_pipe: PipeTiming::new(2, 4),
            fma_pipe: PipeTiming::new(2, 4),
            half_pipe: PipeTiming::new(2, 3),
            fp64_pipe: PipeTiming::new(4, 5),
            sfu_pipe: PipeTiming::new(4, 10),
            lsu_pipe: PipeTiming::new(2, 4),
            tensor_pipe: PipeTiming::new(8, 8),
            uniform_pipe: PipeTiming::new(2, 3),
            control_pipe: PipeTiming::new(2, 2),
            special_pipe: PipeTiming::new(2, 0),
            memory: MemoryConfig::default(),
            tensor: TensorConfig::default(),
            quirks: TranslationQuirks::default(),
            wmma_dtypes: crate::tensor::ALL_DTYPES.to_vec(),
            nextgen: NextGenConfig::default(),
        }
    }
}

impl AmpereConfig {
    /// A100-SXM4 defaults (the paper's testbed, "Tesla A100").
    pub fn a100() -> Self {
        Self::default()
    }

    /// A100 with scaled-down caches (`--small`): identical latencies and
    /// semantics, smaller L1/L2 arrays so the warm pointer-chase loops
    /// finish quickly.  The shared definition behind the CLI flag, CI,
    /// tests and benches.
    pub fn small() -> Self {
        Self::a100().into_small()
    }

    /// Apply the `--small` cache scaling to any architecture's config
    /// (the same knobs [`Self::small`] has always changed): identical
    /// latencies and semantics, smaller L1/L2 arrays so warm
    /// pointer-chase loops finish quickly.
    pub fn into_small(mut self) -> Self {
        self.memory.l2_bytes = 512 * 1024;
        self.memory.l1_bytes = 32 * 1024;
        self
    }

    /// Does this architecture's tensor core support the dtype?
    pub fn supports_wmma(&self, d: crate::tensor::WmmaDtype) -> bool {
        self.wmma_dtypes.contains(&d)
    }

    pub fn pipe(&self, pipe: Pipe) -> PipeTiming {
        match pipe {
            Pipe::Int => self.int_pipe,
            Pipe::Fma => self.fma_pipe,
            Pipe::Half => self.half_pipe,
            Pipe::Fp64 => self.fp64_pipe,
            Pipe::Sfu => self.sfu_pipe,
            Pipe::Lsu => self.lsu_pipe,
            Pipe::Tensor => self.tensor_pipe,
            Pipe::Uniform => self.uniform_pipe,
            Pipe::Control => self.control_pipe,
            Pipe::Special => self.special_pipe,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_a100() {
        let c = AmpereConfig::a100();
        assert_eq!(c.sm_count, 108);
        assert_eq!(c.clock_read_occupancy, 2);
        assert_eq!(c.memory.dram_latency, 290);
        assert_eq!(c.memory.l2_hit_latency, 200);
        assert_eq!(c.memory.l1_hit_latency, 33);
    }

    #[test]
    fn small_only_scales_the_caches() {
        let small = AmpereConfig::small();
        let full = AmpereConfig::a100();
        assert_eq!(small.memory.l2_bytes, 512 * 1024);
        assert_eq!(small.memory.l1_bytes, 32 * 1024);
        // Latencies — the measured quantities — are untouched.
        assert_eq!(small.memory.l1_hit_latency, full.memory.l1_hit_latency);
        assert_eq!(small.memory.l2_hit_latency, full.memory.l2_hit_latency);
        assert_eq!(small.memory.dram_latency, full.memory.dram_latency);
        assert_eq!(small.int_pipe, full.int_pipe);
    }

    #[test]
    fn pipe_lookup_covers_all() {
        let c = AmpereConfig::default();
        for p in ALL_PIPES {
            let t = c.pipe(p);
            assert!(t.occupancy >= 1, "{p:?}");
        }
    }

    #[test]
    fn ampere_defaults_carry_full_quirks_and_wmma_caps() {
        let c = AmpereConfig::a100();
        assert_eq!(c.arch_name, "ampere");
        assert_eq!(c.quirks, TranslationQuirks::default());
        assert!(c.quirks.dep_add_fma_alternation);
        assert!(c.quirks.neg_abs_mov_folding);
        assert!(c.quirks.clock32_depbar);
        assert_eq!(c.wmma_dtypes, crate::tensor::ALL_DTYPES.to_vec());
        assert!(c.supports_wmma(crate::tensor::WmmaDtype::Tf32F32));
    }

    #[test]
    fn into_small_scales_any_config() {
        let mut c = AmpereConfig::a100();
        c.arch_name = "custom".into();
        let s = c.clone().into_small();
        assert_eq!(s.memory.l2_bytes, 512 * 1024);
        assert_eq!(s.memory.l1_bytes, 32 * 1024);
        assert_eq!(s.arch_name, "custom");
        assert_eq!(s.quirks, c.quirks);
    }

    #[test]
    fn issue_ports_default_to_one_per_pipe() {
        // The throughput scheduler's per-arch knobs: one scheduler slot
        // per cycle, one issue port per pipe, unless a spec says more.
        let c = AmpereConfig::default();
        assert_eq!(c.issue_width, 1);
        for p in ALL_PIPES {
            assert_eq!(c.pipe(p).ports, 1, "{p:?}");
        }
        let wide = PipeTiming::with_ports(2, 4, 3);
        assert_eq!(wide.ports, 3);
        assert_eq!(PipeTiming::new(2, 4), PipeTiming::with_ports(2, 4, 1));
    }

    #[test]
    fn nextgen_default_is_the_ampere_capability_set() {
        // sm_80 has LDGSTS; TMA / wgmma / DSMEM are Hopper+.  Keeping
        // the default Ampere-shaped is what preserves
        // `a100() == default()` byte-identity across the arch registry.
        let ng = NextGenConfig::default();
        assert!(ng.cp_async.is_some());
        assert!(ng.tma.is_none());
        assert!(ng.wgmma.is_none());
        assert!(ng.dsmem.is_none());
        assert_eq!(ng.wgmma_flavor, WgmmaFlavor::Hgmma);
        assert_eq!(AmpereConfig::a100().nextgen, ng);

        let pre = NextGenConfig::none();
        assert!(pre.cp_async.is_none() && pre.tma.is_none());
        assert!(pre.wgmma.is_none() && pre.dsmem.is_none());
    }

    #[test]
    fn branch_predication_defaults_are_zero_impact() {
        // Straight-line byte-identity: a taken branch pays nothing
        // beyond the control pipe's occupancy by default, and a
        // squashed (predicated-off) instruction holds exactly its one
        // issue slot.  Custom specs may calibrate both per arch.
        let c = AmpereConfig::a100();
        assert_eq!(c.branch_taken_extra, 0);
        assert_eq!(c.predicated_skip_occupancy, 1);
    }

    #[test]
    fn bandwidth_defaults_are_a100_and_small_leaves_them_alone() {
        // The MLP engine's knobs: one 32 B sector per lane, a full line
        // per cycle out of L1, 32 × 4 B shared banks.  `--small` scales
        // only the cache arrays — bandwidth ceilings are measured
        // quantities, like the latencies.
        let c = AmpereConfig::a100();
        assert_eq!(c.memory.sector_bytes, 32);
        assert_eq!(c.memory.l1_bytes_per_cycle, 128);
        assert_eq!(c.memory.l2_bytes_per_cycle, 64);
        assert_eq!(c.memory.dram_bytes_per_cycle, 32);
        assert_eq!((c.memory.shared_banks, c.memory.shared_bank_bytes), (32, 4));
        let s = AmpereConfig::small();
        assert_eq!(s.memory.l1_bytes_per_cycle, c.memory.l1_bytes_per_cycle);
        assert_eq!(s.memory.dram_bytes_per_cycle, c.memory.dram_bytes_per_cycle);
        assert_eq!(s.memory.shared_banks, c.memory.shared_banks);
    }

    #[test]
    fn occupancy_reflects_lane_counts() {
        // 32-thread warp over {16, 16, 8, 4} lanes per partition.
        let c = AmpereConfig::default();
        assert_eq!(c.int_pipe.occupancy, 2);
        assert_eq!(c.fma_pipe.occupancy, 2);
        assert_eq!(c.fp64_pipe.occupancy, 4);
        assert_eq!(c.sfu_pipe.occupancy, 4);
    }
}
