//! Criterion-style timing harness for `cargo bench` (no external
//! criterion in the build environment).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::from_args("table4_memory");
//! b.bench("table4_memory", || { ... });
//! b.finish();
//! ```
//!
//! Reports min / median / mean / p95 wall-clock per iteration and writes
//! `target/ubench/<name>.json` so the §Perf pass can diff before/after.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub min_ns: u128,
    pub median_ns: u128,
    pub mean_ns: u128,
    pub p95_ns: u128,
}

impl Stats {
    pub fn line(&self) -> String {
        format!(
            "{:<36} {:>6} iters  min {}  med {}  mean {}  p95 {}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

fn fmt_ns(ns: u128) -> String {
    if ns >= 1_000_000_000 {
        format!("{:>8.3}s ", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:>8.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:>8.3}µs", ns as f64 / 1e3)
    } else {
        format!("{ns:>8}ns")
    }
}

pub struct Bench {
    target: String,
    /// Minimum total sampling time per benchmark.
    pub budget: Duration,
    /// Max samples.
    pub max_samples: u64,
    results: Vec<Stats>,
}

impl Bench {
    /// Reads `--bench` / `--quick` style args (ignores unknown flags so
    /// `cargo bench -- --quick` works).
    pub fn from_args(target: &str) -> Bench {
        let quick = std::env::args().any(|a| a == "--quick");
        Bench {
            target: target.to_string(),
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_samples: if quick { 10 } else { 60 },
            results: Vec::new(),
        }
    }

    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Stats {
        // warm-up
        bb(f());
        let mut samples: Vec<u128> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && (samples.len() as u64) < self.max_samples {
            let t = Instant::now();
            bb(f());
            samples.push(t.elapsed().as_nanos());
        }
        samples.sort_unstable();
        let n = samples.len();
        let stats = Stats {
            name: name.to_string(),
            iters: n as u64,
            min_ns: samples[0],
            median_ns: samples[n / 2],
            mean_ns: samples.iter().sum::<u128>() / n as u128,
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Serialize the collected stats (shared by both output files).
    fn results_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let arr: Vec<Value> = self
            .results
            .iter()
            .map(|s| {
                Value::obj()
                    .set("name", s.name.as_str())
                    .set("iters", s.iters)
                    .set("min_ns", s.min_ns as u64)
                    .set("median_ns", s.median_ns as u64)
                    .set("mean_ns", s.mean_ns as u64)
                    .set("p95_ns", s.p95_ns as u64)
            })
            .collect();
        Value::Arr(arr)
    }

    /// Write results to `target/ubench/<target>.json` for §Perf diffing,
    /// and to `BENCH_<target>.json` in the working directory so the perf
    /// trajectory stays machine-readable across PRs (before/after files
    /// survive `cargo clean`; diff them to demonstrate speedups).
    pub fn finish(self) {
        use crate::util::json::Value;
        let results = self.results_json();
        let _ = std::fs::create_dir_all("target/ubench");
        let path = format!("target/ubench/{}.json", self.target);
        let _ = std::fs::write(&path, crate::util::json::to_string_pretty(&results));
        println!("(wrote {path})");

        let bench_path = format!("BENCH_{}.json", self.target);
        let doc = Value::obj()
            .set("bench", self.target.as_str())
            .set("results", results);
        let _ = std::fs::write(&bench_path, crate::util::json::to_string_pretty(&doc));
        println!("(wrote {bench_path})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            target: "test".into(),
            budget: Duration::from_millis(20),
            max_samples: 5,
            results: Vec::new(),
        };
        let s = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 1);
        assert!(s.min_ns > 0);
        assert!(s.min_ns <= s.p95_ns);
    }

    #[test]
    fn results_serialize_with_required_fields() {
        let mut b = Bench {
            target: "test".into(),
            budget: Duration::from_millis(20),
            max_samples: 2,
            results: Vec::new(),
        };
        b.bench("spin", || 1 + 1);
        let v = b.results_json();
        let row = v.idx(0).unwrap();
        for key in ["name", "iters", "median_ns", "p95_ns"] {
            assert!(row.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(500).contains("ns"));
        assert!(fmt_ns(5_000).contains("µs"));
        assert!(fmt_ns(5_000_000).contains("ms"));
        assert!(fmt_ns(5_000_000_000).contains('s'));
    }
}
