//! Minimal JSON: a recursive-descent parser and a serializer.
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number forms the
//! suite never produces; used for `artifacts/manifest.json` and the
//! CLI's `--json` output.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Strict: only whole, in-range, non-negative numbers convert —
    /// `-3` or `2.7` return `None` instead of silently truncating, so
    /// schema loaders (e.g. the oracle's `LatencyModel::from_json`)
    /// reject corrupt files rather than absorbing them.
    pub fn as_u64(&self) -> Option<u64> {
        // `u64::MAX as f64` rounds up to exactly 2^64, which is *not*
        // representable — so the bound is strict.
        match self.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 && n < u64::MAX as f64 => Some(n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders (for --json output) -------------------------------
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(m) = &mut self {
            m.insert(key.to_string(), v.into());
        }
        self
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Arr(v)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(src: &str) -> Result<Value, JsonError> {
    let b: Vec<char> = src.chars().collect();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser {
    b: Vec<char>,
    i: usize,
}

impl Parser {
    fn err(&self, m: &str) -> JsonError {
        JsonError { offset: self.i, message: m.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: char) -> bool {
        if self.peek() == Some(c) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, c: char) -> Result<(), JsonError> {
        if self.eat(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {c:?}")))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        for c in s.chars() {
            if !self.eat(c) {
                return Err(self.err(&format!("bad literal (wanted {s})")));
            }
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Value::Str(self.string()?)),
            Some('t') => self.lit("true", Value::Bool(true)),
            Some('f') => self.lit("false", Value::Bool(false)),
            Some('n') => self.lit("null", Value::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(self.err(&format!("unexpected {other:?}"))),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect('{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.eat('}') {
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            if self.eat(',') {
                continue;
            }
            self.expect('}')?;
            return Ok(Value::Obj(m));
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect('[')?;
        let mut v = Vec::new();
        self.ws();
        if self.eat(']') {
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            if self.eat(',') {
                continue;
            }
            self.expect(']')?;
            return Ok(Value::Arr(v));
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect('"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some('"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some('\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        '"' => s.push('"'),
                        '\\' => s.push('\\'),
                        '/' => s.push('/'),
                        'b' => s.push('\u{8}'),
                        'f' => s.push('\u{c}'),
                        'n' => s.push('\n'),
                        'r' => s.push('\r'),
                        't' => s.push('\t'),
                        'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let c = self.peek().ok_or_else(|| self.err("bad \\u"))?;
                                code = code * 16
                                    + c.to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                                self.i += 1;
                            }
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(self.err(&format!("bad escape \\{other}"))),
                    }
                }
                Some(c) => {
                    s.push(c);
                    self.i += 1;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.eat('-') {}
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.eat('.') {
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some('e' | 'E')) {
            self.i += 1;
            if matches!(self.peek(), Some('+' | '-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s: String = self.b[start..self.i].iter().collect();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| self.err(&e.to_string()))
    }
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_compact(v, &mut out);
    out
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => escape(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_compact(x, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad1 = "  ".repeat(indent + 1);
    match v {
        Value::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, x) in a.iter().enumerate() {
                out.push_str(&pad1);
                write_value(x, indent + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push(']');
        }
        Value::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                out.push_str(&pad1);
                escape(k, out);
                out.push_str(": ");
                write_value(x, indent + 1, out);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push('}');
        }
        other => write_compact(other, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let src = r#"{
  "wmma_f16_f16": {
    "file": "wmma_f16_f16.hlo.txt",
    "args": [{"shape": [16, 16], "dtype": "float32"}]
  }
}"#;
        let v = parse(src).unwrap();
        let meta = v.get("wmma_f16_f16").unwrap();
        assert_eq!(meta.get("file").unwrap().as_str(), Some("wmma_f16_f16.hlo.txt"));
        let arg0 = meta.get("args").unwrap().idx(0).unwrap();
        assert_eq!(arg0.get("shape").unwrap().idx(0).unwrap().as_usize(), Some(16));
        assert_eq!(arg0.get("dtype").unwrap().as_str(), Some("float32"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null, "e": {}}"#;
        let v = parse(src).unwrap();
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
        let p = to_string_pretty(&v);
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5E-1").unwrap().as_f64(), Some(-0.25));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn builder() {
        let v = Value::obj().set("x", 3u64).set("y", "hi").set("z", true);
        assert_eq!(to_string(&v), r#"{"x":3,"y":"hi","z":true}"#);
    }
}
