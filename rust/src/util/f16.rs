//! IEEE binary16 (`F16`) and bfloat16 (`Bf16`) — bit-exact conversion
//! and value semantics for the simulator's half-precision instructions
//! and WMMA fragment dtypes (Table III).
//!
//! Round-to-nearest-even on narrowing, exact on widening, full
//! subnormal/Inf/NaN handling (Fasi et al. showed Ampere TCs keep
//! subnormals — so do we).

/// IEEE 754 binary16.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);

    pub fn from_bits(b: u16) -> F16 {
        F16(b)
    }

    pub fn to_bits(self) -> u16 {
        self.0
    }

    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    pub fn from_f64(x: f64) -> F16 {
        F16(f32_to_f16_bits(x as f32))
    }

    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    pub fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
}

/// bfloat16: f32 with the low 16 mantissa bits dropped (RNE).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub fn from_bits(b: u16) -> Bf16 {
        Bf16(b)
    }

    pub fn to_bits(self) -> u16 {
        self.0
    }

    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet, preserve payload msb
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // round-to-nearest-even on the dropped 16 bits
        let round_bit = (bits >> 15) & 1;
        let sticky = bits & 0x7FFF;
        let mut hi = (bits >> 16) as u16;
        if round_bit == 1 && (sticky != 0 || (hi & 1) == 1) {
            hi = hi.wrapping_add(1);
        }
        Bf16(hi)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// f32 → f16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00 | ((man >> 13) as u16 & 0x03FF) | u16::from(man >> 13 == 0)
        };
    }

    // unbiased exponent
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7C00; // overflow → Inf
    }
    if e >= -14 {
        // normal half
        let mut h = ((e + 15) as u16) << 10 | ((man >> 13) as u16);
        // RNE on the dropped 13 bits
        let round = man & 0x1FFF;
        if round > 0x1000 || (round == 0x1000 && (h & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent — correct
        }
        return sign | h;
    }
    if e >= -25 {
        // subnormal half
        let full = man | 0x0080_0000; // implicit bit
        let shift = (-14 - e) as u32 + 13;
        let mut h = (full >> shift) as u16;
        let dropped = full & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if dropped > halfway || (dropped == halfway && (h & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return sign | h;
    }
    sign // underflow → ±0
}

/// f16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: value = m × 2^-24; normalize into f32
            let p = 31 - m.leading_zeros(); // msb position, 0..=9
            let e = p + 103; // (p − 24) + 127
            let mm = (m << (23 - p)) & 0x007F_FFFF;
            sign | (e << 23) | mm
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13) | 0x0040_0000,
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(F16::from_f32(1.0).to_bits(), 0x3C00);
        assert_eq!(F16::from_f32(-2.0).to_bits(), 0xC000);
        assert_eq!(F16::from_f32(0.5).to_bits(), 0x3800);
        assert_eq!(F16::from_f32(65504.0).to_bits(), 0x7BFF); // max finite
        assert_eq!(F16::from_f32(0.0).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-0.0).to_bits(), 0x8000);
    }

    #[test]
    fn widen_is_exact_for_all_finite_halves() {
        for bits in 0u16..=0xFFFF {
            let f = f16_bits_to_f32(bits);
            if f.is_finite() {
                // narrowing back must reproduce the same bit pattern
                let back = f32_to_f16_bits(f);
                assert_eq!(back, bits, "bits {bits:#06x} → {f} → {back:#06x}");
            }
        }
    }

    #[test]
    fn overflow_and_inf() {
        assert_eq!(F16::from_f32(1e6).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(f32::INFINITY).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(f32::NEG_INFINITY).to_bits(), 0xFC00);
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn subnormals_preserved() {
        // smallest positive subnormal half = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(F16::from_f32(tiny).to_bits(), 0x0001);
        assert_eq!(f16_bits_to_f32(0x0001), tiny);
    }

    #[test]
    fn rne_rounding() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next half;
        // RNE keeps the even (1.0).
        let x = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C00);
        // 1 + 3·2^-11 rounds up to odd+1
        let y = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(y), 0x3C02);
    }

    #[test]
    fn bf16_basics() {
        assert_eq!(Bf16::from_f32(1.0).to_bits(), 0x3F80);
        assert_eq!(Bf16::from_f32(-2.5).to_f32(), -2.5);
        // RNE at the 16-bit boundary
        let x = f32::from_bits(0x3F80_8000); // halfway
        assert_eq!(Bf16::from_f32(x).to_bits(), 0x3F80); // even stays
        let y = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(y).to_bits(), 0x3F82); // odd rounds up
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }
}
