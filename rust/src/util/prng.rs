//! Deterministic xorshift64* PRNG — the substrate for the repo's
//! property-based tests (no external proptest available; tests draw
//! seeded random programs/values and shrink by re-seeding).

#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection-free bias is fine for test generation
        self.next_u64() % n
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Effective sweep depth: `default`, deepened by the `FUZZ_CASES` env
/// var.  Deepen-only (`max`), never shallower — CI exporting
/// `FUZZ_CASES=200` must not silently *reduce* a property that already
/// runs more cases locally.
pub fn fuzz_cases(default: u64) -> u64 {
    std::env::var("FUZZ_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map_or(default, |v| v.max(default))
}

/// Run a property over `n` seeded cases; on failure report the seed so
/// the case replays deterministically.
///
/// The `FUZZ_CASES` env var deepens `n` globally (see [`fuzz_cases`]),
/// so CI can run every property sweep deep (e.g. `FUZZ_CASES=500`)
/// while local `cargo test -q` stays fast.  Seeds derive from the case
/// index alone, so a failure found at any depth replays at that depth
/// or deeper.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, n: u64, f: F) {
    let n = fuzz_cases(n);
    for case in 0..n {
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1);
        let mut rng = Rng::new(seed);
        if let Err(m) = f(&mut rng) {
            panic!(
                "property {name} failed (case {case}, seed {seed:#x}): {m}\n  \
                 replay: rerun with FUZZ_CASES>={} — fuzz-driven properties print \
                 their own `repro fuzz --seed <s> --cases 1` command in the message \
                 above",
                case + 1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            let x = r.range(-5, 5);
            assert!((-5..5).contains(&x));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(99);
        let mut buckets = [0u32; 8];
        for _ in 0..8000 {
            buckets[r.below(8) as usize] += 1;
        }
        for b in buckets {
            assert!((800..1200).contains(&b), "bucket {b}");
        }
    }

    #[test]
    #[should_panic(expected = "property demo failed")]
    fn check_reports_seed() {
        check("demo", 5, |rng| {
            if rng.below(2) == 0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }
}
