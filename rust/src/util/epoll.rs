//! A `libc`-free epoll wrapper over raw Linux syscalls.
//!
//! The serving reactor ([`crate::oracle::serve`]) needs readiness
//! notification for thousands of nonblocking sockets, but the crate
//! vendors no FFI bindings — so the three epoll calls are issued
//! directly with inline assembly, exactly the way `libc` would. The
//! surface is the minimal level-triggered subset the reactor uses:
//! create, add/modify/delete an interest, and wait.
//!
//! Only compiled on Linux (x86_64 / aarch64); every other target keeps
//! the thread-per-connection serving backend, so tier-1 stays green
//! everywhere without a network or an external crate.

use std::io;

/// Readiness: the fd has bytes to read (or a pending accept).
pub const EPOLLIN: u32 = 0x001;
/// Readiness: the fd can accept writes without blocking.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition — always reported, never needs registering.
pub const EPOLLERR: u32 = 0x008;
/// Hangup — always reported, never needs registering.
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its writing half (half-open connection).
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CLOEXEC: usize = 0x80000;
const EPOLL_CTL_ADD: usize = 1;
const EPOLL_CTL_DEL: usize = 2;
const EPOLL_CTL_MOD: usize = 3;

#[cfg(target_arch = "x86_64")]
mod nr {
    pub const CLOSE: usize = 3;
    pub const EPOLL_WAIT: usize = 232;
    pub const EPOLL_CTL: usize = 233;
    pub const EPOLL_CREATE1: usize = 291;
}

#[cfg(target_arch = "aarch64")]
mod nr {
    pub const EPOLL_CREATE1: usize = 20;
    pub const EPOLL_CTL: usize = 21;
    // aarch64 has no plain epoll_wait; epoll_pwait with a null sigmask
    // is the kernel's own definition of it.
    pub const EPOLL_WAIT: usize = 22;
    pub const CLOSE: usize = 57;
}

/// One readiness record, laid out exactly as the kernel writes it.
///
/// x86_64 is the one ABI where `struct epoll_event` is packed (the
/// 32-bit layout was kept for compatibility); everywhere else it has
/// natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

/// One readiness record, laid out exactly as the kernel writes it.
#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    /// An empty record for pre-sizing the `wait` buffer.
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }

    /// The readiness bits the kernel reported (`EPOLLIN | …`).
    pub fn events(&self) -> u32 {
        // By-value copy: field *references* into a packed struct are
        // UB-adjacent, plain reads are fine.
        self.events
    }

    /// The caller's token, round-tripped verbatim from `add`/`modify`.
    pub fn token(&self) -> u64 {
        self.data
    }
}

/// raw syscall, 4 explicit arguments (enough for every epoll call).
#[cfg(target_arch = "x86_64")]
unsafe fn syscall4(n: usize, a1: usize, a2: usize, a3: usize, a4: usize) -> isize {
    let ret: isize;
    // The syscall instruction clobbers rcx (return rip) and r11
    // (rflags); the kernel preserves everything else we use.
    std::arch::asm!(
        "syscall",
        inlateout("rax") n as isize => ret,
        in("rdi") a1,
        in("rsi") a2,
        in("rdx") a3,
        in("r10") a4,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

/// raw syscall, 4 explicit arguments (enough for every epoll call).
#[cfg(target_arch = "aarch64")]
unsafe fn syscall4(n: usize, a1: usize, a2: usize, a3: usize, a4: usize) -> isize {
    let ret: isize;
    // x4/x5 are zeroed so epoll_pwait sees a null sigmask: that makes
    // it behave exactly like x86_64's epoll_wait.
    std::arch::asm!(
        "svc 0",
        in("x8") n,
        inlateout("x0") a1 => ret,
        in("x1") a2,
        in("x2") a3,
        in("x3") a4,
        in("x4") 0usize,
        in("x5") 0usize,
        options(nostack),
    );
    ret
}

/// Raw returns are `-errno` on failure, exactly like the kernel ABI.
fn check(ret: isize) -> io::Result<usize> {
    if ret < 0 {
        Err(io::Error::from_raw_os_error(-ret as i32))
    } else {
        Ok(ret as usize)
    }
}

/// An epoll instance (level-triggered). Closes its fd on drop.
pub struct Epoll {
    fd: i32,
}

impl Epoll {
    /// `epoll_create1(EPOLL_CLOEXEC)`.
    pub fn new() -> io::Result<Epoll> {
        let ret = unsafe { syscall4(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0) };
        check(ret).map(|fd| Epoll { fd: fd as i32 })
    }

    /// Start watching `fd` for `events`, tagging reports with `token`.
    pub fn add(&self, fd: i32, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Replace the interest set (and token) for an already-added `fd`.
    pub fn modify(&self, fd: i32, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Stop watching `fd`.
    pub fn del(&self, fd: i32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    fn ctl(&self, op: usize, fd: i32, events: u32, token: u64) -> io::Result<()> {
        // Pre-2.6.9 kernels require a non-null event pointer even for
        // DEL, so one is always passed.
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        let ret = unsafe {
            syscall4(
                nr::EPOLL_CTL,
                self.fd as usize,
                op,
                fd as usize,
                &mut ev as *mut EpollEvent as usize,
            )
        };
        check(ret).map(|_| ())
    }

    /// Block up to `timeout_ms` (0 = poll, negative = forever) and
    /// fill `events` with ready records; returns how many. EINTR is
    /// retried internally.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let ret = unsafe {
                syscall4(
                    nr::EPOLL_WAIT,
                    self.fd as usize,
                    events.as_mut_ptr() as usize,
                    events.len(),
                    timeout_ms as isize as usize,
                )
            };
            match check(ret) {
                Ok(n) => return Ok(n),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            syscall4(nr::CLOSE, self.fd as usize, 0, 0, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn readiness_tracks_socket_state() {
        let ep = Epoll::new().unwrap();
        let (mut a, mut b) = UnixStream::pair().unwrap();
        ep.add(b.as_raw_fd(), EPOLLIN, 7).unwrap();
        let mut evs = [EpollEvent::zeroed(); 8];

        // Nothing buffered yet: a zero-timeout wait reports nothing.
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);

        a.write_all(b"x").unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 7);
        assert_ne!(evs[0].events() & EPOLLIN, 0);

        // Level-triggered: still ready until the byte is consumed.
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 1);
        let mut byte = [0u8; 1];
        b.read_exact(&mut byte).unwrap();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);

        // An empty send buffer reports EPOLLOUT immediately, and the
        // token travels with the modify.
        ep.modify(b.as_raw_fd(), EPOLLIN | EPOLLOUT, 9).unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 9);
        assert_ne!(evs[0].events() & EPOLLOUT, 0);

        // After del, new bytes no longer wake the instance.
        ep.del(b.as_raw_fd()).unwrap();
        a.write_all(b"y").unwrap();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn peer_close_reports_hangup_or_readable_eof() {
        let ep = Epoll::new().unwrap();
        let (a, b) = UnixStream::pair().unwrap();
        ep.add(b.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 1).unwrap();
        drop(a);
        let mut evs = [EpollEvent::zeroed(); 4];
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_ne!(evs[0].events() & (EPOLLIN | EPOLLHUP | EPOLLRDHUP), 0);
    }

    #[test]
    fn double_add_is_an_error_modify_is_not() {
        let ep = Epoll::new().unwrap();
        let (_a, b) = UnixStream::pair().unwrap();
        ep.add(b.as_raw_fd(), EPOLLIN, 1).unwrap();
        assert!(ep.add(b.as_raw_fd(), EPOLLIN, 2).is_err());
        ep.modify(b.as_raw_fd(), EPOLLOUT, 3).unwrap();
    }
}
