//! Self-contained infrastructure substrates.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so everything else a benchmark-infra repo normally pulls in
//! is implemented here from scratch (DESIGN.md §Substitutions):
//!
//! * [`json`]  — JSON parser + serializer (artifact manifests, `--json`);
//! * [`f16`]   — IEEE binary16 and bfloat16 conversion/arithmetic;
//! * [`prng`]  — deterministic xorshift PRNG for property-based tests;
//! * [`bench`] — the criterion-style timing harness `cargo bench` runs;
//! * `epoll`   — on Linux, a `libc`-free readiness shim (raw syscalls
//!   via inline asm) behind the serving reactor.

pub mod bench;
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub mod epoll;
pub mod f16;
pub mod json;
pub mod prng;
