//! Table renderers: print each experiment in the paper's row format next
//! to the published values, plus a shape-match summary for EXPERIMENTS.md.

use crate::microbench::alu::{Amortization, DepIndep, RowResult};
use crate::microbench::gemm::GemmRow;
use crate::microbench::insights::{Fig4, Insight1, Insight3, SignPair};
use crate::microbench::memory::MemResult;
use crate::microbench::mlp::MlpRow;
use crate::microbench::throughput::ThroughputRow;
use crate::microbench::wmma::WmmaResult;
use crate::microbench::MatchGrade;
use std::fmt::Write;

fn hr(out: &mut String, widths: &[usize]) {
    for w in widths {
        let _ = write!(out, "+{}", "-".repeat(w + 2));
    }
    out.push_str("+\n");
}

fn row_line(out: &mut String, widths: &[usize], cells: &[String]) {
    for (w, c) in widths.iter().zip(cells) {
        let _ = write!(out, "| {c:<w$} ");
    }
    out.push_str("|\n");
}

/// Generic table printer.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "\n== {title} ==");
    hr(&mut out, &widths);
    row_line(&mut out, &widths, &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    hr(&mut out, &widths);
    for r in rows {
        row_line(&mut out, &widths, r);
    }
    hr(&mut out, &widths);
    out
}

pub fn grade_str(g: MatchGrade) -> &'static str {
    match g {
        MatchGrade::Exact => "exact",
        MatchGrade::Close => "close",
        MatchGrade::Off => "OFF",
    }
}

pub fn table1(rows: &[Amortization]) -> String {
    render_table(
        "Table I — CPI vs #instructions (add.u32, cold pipe)",
        &["# instrs", "CPI (measured)", "CPI (paper)"],
        &rows
            .iter()
            .map(|r| vec![r.n.to_string(), r.cpi.to_string(), r.paper_cpi.to_string()])
            .collect::<Vec<_>>(),
    )
}

pub fn table2(rows: &[DepIndep]) -> String {
    render_table(
        "Table II — dependent vs independent CPI",
        &["instr", "dep", "dep(paper)", "indep", "indep(paper)"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.dep_cpi.to_string(),
                    r.paper_dep.to_string(),
                    r.indep_cpi.to_string(),
                    r.paper_indep.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

pub fn table3(rows: &[WmmaResult]) -> String {
    render_table(
        "Table III — tensor-core latency & throughput",
        &[
            "dtype",
            "cycles",
            "paper",
            "SASS (measured)",
            "SASS (paper)",
            "TOPS meas-theo",
            "paper meas-theo",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.dtype_key.to_string(),
                    r.cycles.to_string(),
                    r.paper_cycles.to_string(),
                    r.sass.clone(),
                    r.paper_sass.clone(),
                    format!(
                        "{:.0}-{:.1}",
                        r.throughput.measured_tops, r.throughput.theoretical_tops
                    ),
                    format!("{:.0}-{:.1}", r.paper_measured_tops, r.paper_theoretical_tops),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

pub fn table4(rows: &[MemResult]) -> String {
    render_table(
        "Table IV — memory access latencies",
        &["Memory type", "CPI (measured)", "CPI (paper)"],
        &rows
            .iter()
            .map(|r| vec![r.level.name().to_string(), r.cpi.to_string(), r.paper.to_string()])
            .collect::<Vec<_>>(),
    )
}

pub fn table5(rows: &[RowResult]) -> String {
    let mut body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.measured.mapping.clone(),
                r.paper_sass.clone(),
                r.measured.cpi.to_string(),
                r.paper_cycles.clone(),
                grade_str(r.cycles_grade).to_string(),
            ]
        })
        .collect();
    let exact = rows.iter().filter(|r| r.cycles_grade == MatchGrade::Exact).count();
    let close = rows.iter().filter(|r| r.cycles_grade == MatchGrade::Close).count();
    body.push(vec![
        format!("[{} rows]", rows.len()),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{exact} exact / {close} close"),
    ]);
    render_table(
        "Table V — PTX→SASS mapping and clock cycles",
        &["PTX", "SASS (measured)", "SASS (paper)", "cyc", "paper", "grade"],
        &body,
    )
}

/// `repro gemm`: the whole-kernel prediction sweep — live simulation vs
/// the protocol replay per tile kernel, with the exact-match verdict.
pub fn gemm(rows: &[GemmRow]) -> String {
    let mut body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                r.dtype.clone(),
                format!("{}x{}x{}", r.m, r.n, r.k),
                r.ktiles.to_string(),
                r.sim_cycles.to_string(),
                r.predicted_cycles.to_string(),
                r.replayed_sass.to_string(),
                if r.matches { "exact" } else { "MISMATCH" }.to_string(),
            ]
        })
        .collect();
    let exact = rows.iter().filter(|r| r.matches).count();
    body.push(vec![
        format!("[{} kernels]", rows.len()),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{exact}/{} exact", rows.len()),
    ]);
    render_table(
        "GEMM — whole-kernel cycles, simulated vs predicted",
        &["kernel", "dtype", "tile", "ktiles", "sim", "predicted", "sass", "verdict"],
        &body,
    )
}
/// (`500 → "0.500"`): the sweep stores IPC in exact integer milli-units
/// so text, JSON, the oracle model and `compare` all agree bit for bit.
pub fn ipc_milli(m: u64) -> String {
    format!("{}.{:03}", m / 1000, m % 1000)
}

/// `repro throughput`: achieved IPC per resident-warp count for every
/// registry row and supported WMMA dtype, plus the saturation summary.
pub fn throughput(rows: &[ThroughputRow]) -> String {
    let counts: Vec<u32> = rows
        .first()
        .map(|r| r.points.iter().map(|p| p.warps).collect())
        .unwrap_or_default();
    let mut headers: Vec<String> =
        vec!["instr".into(), "kind".into(), "n".into(), "CPI@1w".into()];
    for w in &counts {
        headers.push(format!("IPC@{w}w"));
    }
    headers.push("peak IPC".into());
    headers.push("warps@peak".into());
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();

    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut cells = vec![
                r.name.clone(),
                r.kind.to_string(),
                r.n.to_string(),
                r.cpi_1w.to_string(),
            ];
            for p in &r.points {
                cells.push(ipc_milli(p.ipc_milli));
            }
            cells.push(ipc_milli(r.peak_ipc_milli));
            cells.push(r.warps_to_peak.to_string());
            cells
        })
        .collect();
    render_table(
        &format!("Throughput — achieved IPC vs resident warps ({} rows)", rows.len()),
        &header_refs,
        &body,
    )
}

/// `repro mlp`: per-level latency-vs-MLP saturation curves — the
/// measured Table IV anchor, the spec-derived service cost, the
/// bandwidth ceiling and the per-access cost at every swept degree
/// (milli-cycle integers, rendered through the same exact encoding as
/// IPC).
pub fn mlp(rows: &[MlpRow]) -> String {
    let degrees: Vec<u32> = rows
        .first()
        .map(|r| r.points.iter().map(|p| p.mlp).collect())
        .unwrap_or_default();
    let mut headers: Vec<String> = vec![
        "level".into(),
        "latency".into(),
        "service".into(),
        "peak bw".into(),
        "knee".into(),
    ];
    for d in &degrees {
        headers.push(format!("cyc@{d}"));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut cells = vec![
                r.level.key().to_string(),
                r.latency.to_string(),
                r.service.to_string(),
                ipc_milli(r.peak_bw_milli),
                r.knee_mlp.to_string(),
            ];
            for p in &r.points {
                cells.push(ipc_milli(p.per_access_milli));
            }
            cells
        })
        .collect();
    render_table(
        "MLP — per-access cycles vs memory-level parallelism (bw in accesses/cycle)",
        &header_refs,
        &body,
    )
}

pub fn fig4(f: &Fig4) -> String {
    render_table(
        "Fig. 4 — clock register width",
        &["variant", "CPI", "paper"],
        &[
            vec!["32-bit clocks (barrier)".into(), f.cpi_32bit.to_string(), "13".into()],
            vec!["64-bit clocks (CS2R)".into(), f.cpi_64bit.to_string(), "2".into()],
        ],
    )
}

pub fn insights(i1: &Insight1, i2: &[SignPair], i3: &[Insight3]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "\n== Insight 1 — integer mad on the FP pipe ==\n  mad.lo.u32 -> {} ; mixed-pipe CPI {} vs same-pipe {}",
        i1.mad_mapping, i1.mixed_cpi, i1.same_pipe_cpi
    );
    out.push_str(&render_table(
        "Insight 2 — signed vs unsigned",
        &["pair", "unsigned SASS", "signed SASS", "differs", "paper"],
        &i2.iter()
            .map(|p| {
                vec![
                    p.base.clone(),
                    p.unsigned_mapping.clone(),
                    p.signed_mapping.clone(),
                    p.differs.to_string(),
                    p.paper_expects_difference.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    ));
    out.push_str(&render_table(
        "Insight 3 — init style changes the mapping",
        &["op", "mov-init", "add-init"],
        &i3.iter()
            .map(|i| vec![i.op.clone(), i.mov_init_mapping.clone(), i.add_init_mapping.clone()])
            .collect::<Vec<_>>(),
    ));
    out
}

// ---- cross-architecture comparison (`repro compare --arch a,b`) -----

use crate::util::json::Value;

/// The per-arch results `compare`/`compare_json` tabulate: one
/// campaign's Table V / Table IV / Table III rows per architecture, in
/// `--arch` order.  Table V and Table IV rows align by construction
/// (same registry, same level list, every architecture); Table III rows
/// align by dtype key, absent where an architecture's WMMA capability
/// table omits the dtype.
pub struct ArchResults<'a> {
    pub arch: &'a str,
    pub table5: &'a [RowResult],
    pub table4: &'a [MemResult],
    pub table3: &'a [WmmaResult],
    /// Multi-warp throughput sweep rows (aligned across architectures
    /// by row *name*, since capability tables differ).  Pass an empty
    /// slice to omit the cross-arch IPC table.
    pub throughput: &'a [ThroughputRow],
    /// Next-gen family measurements (aligned by family key; a family an
    /// architecture lacks comes back `available: false` and renders as
    /// "-").  Pass an empty slice to omit the cross-arch family table.
    pub nextgen: &'a [crate::isa::NextGenMeasurement],
    /// Latency-vs-MLP saturation rows (aligned by level key; a level an
    /// architecture lacks renders as "-"/null).  Pass an empty slice to
    /// omit the cross-arch bandwidth table.
    pub mlp: &'a [MlpRow],
}

/// Deltas are reported against the first (baseline) architecture.
fn delta(base: u64, other: u64) -> String {
    let d = other as i64 - base as i64;
    if d == 0 {
        "=".to_string()
    } else {
        format!("{d:+}")
    }
}

/// Cross-architecture delta tables: every Table V row's CPI per arch
/// (with the signed delta vs the first arch), Table IV per level, and
/// Table III per dtype ("-" where a generation lacks the dtype).
pub fn compare(results: &[ArchResults<'_>]) -> String {
    assert!(results.len() >= 2, "compare needs at least two architectures");
    let base = &results[0];
    let mut out = String::new();

    let mut headers: Vec<String> = vec!["PTX".into()];
    for r in results {
        headers.push(format!("cyc@{}", r.arch));
    }
    for r in &results[1..] {
        headers.push(format!("Δ {}", r.arch));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();

    let rows: Vec<Vec<String>> = base
        .table5
        .iter()
        .enumerate()
        .map(|(i, row)| {
            let mut cells = vec![row.name.clone()];
            for r in results {
                cells.push(r.table5[i].measured.cpi.to_string());
            }
            for r in &results[1..] {
                cells.push(delta(row.measured.cpi, r.table5[i].measured.cpi));
            }
            cells
        })
        .collect();
    out.push_str(&render_table(
        &format!(
            "Cross-arch Table V — CPI per instruction ({} rows, Δ vs {})",
            base.table5.len(),
            base.arch
        ),
        &header_refs,
        &rows,
    ));

    let mem_headers: Vec<&str> = std::iter::once("Memory type")
        .chain(results.iter().map(|r| r.arch))
        .collect();
    let mem_rows: Vec<Vec<String>> = base
        .table4
        .iter()
        .enumerate()
        .map(|(i, row)| {
            let mut cells = vec![row.level.name().to_string()];
            for r in results {
                cells.push(r.table4[i].cpi.to_string());
            }
            cells
        })
        .collect();
    out.push_str(&render_table("Cross-arch Table IV — memory latencies", &mem_headers, &mem_rows));

    let wmma_headers: Vec<&str> = std::iter::once("dtype")
        .chain(results.iter().map(|r| r.arch))
        .collect();
    let wmma_rows: Vec<Vec<String>> = crate::tensor::ALL_DTYPES
        .iter()
        .map(|d| {
            let mut cells = vec![d.key().to_string()];
            for r in results {
                cells.push(
                    r.table3
                        .iter()
                        .find(|w| w.dtype_key == d.key())
                        .map(|w| format!("{} cyc", w.cycles))
                        .unwrap_or_else(|| "-".to_string()),
                );
            }
            cells
        })
        .collect();
    out.push_str(&render_table(
        "Cross-arch Table III — WMMA latency ('-' = dtype unsupported)",
        &wmma_headers,
        &wmma_rows,
    ));

    if results.iter().all(|r| !r.throughput.is_empty()) {
        let mut tp_headers: Vec<String> = vec!["instr".into()];
        for r in results {
            tp_headers.push(format!("peak IPC@{}", r.arch));
        }
        for r in &results[1..] {
            tp_headers.push(format!("Δm {}", r.arch));
        }
        for r in results {
            tp_headers.push(format!("w@peak {}", r.arch));
        }
        let tp_header_refs: Vec<&str> = tp_headers.iter().map(String::as_str).collect();
        let tp_rows: Vec<Vec<String>> = base
            .throughput
            .iter()
            .map(|row| {
                let find = |r: &ArchResults<'_>| {
                    r.throughput.iter().find(|t| t.name == row.name)
                };
                let mut cells = vec![row.name.clone()];
                for r in results {
                    cells.push(
                        find(r)
                            .map(|t| ipc_milli(t.peak_ipc_milli))
                            .unwrap_or_else(|| "-".to_string()),
                    );
                }
                for r in &results[1..] {
                    cells.push(
                        find(r)
                            .map(|t| {
                                let d = t.peak_ipc_milli as i64 - row.peak_ipc_milli as i64;
                                if d == 0 { "=".to_string() } else { format!("{d:+}") }
                            })
                            .unwrap_or_else(|| "-".to_string()),
                    );
                }
                for r in results {
                    cells.push(
                        find(r)
                            .map(|t| t.warps_to_peak.to_string())
                            .unwrap_or_else(|| "-".to_string()),
                    );
                }
                cells
            })
            .collect();
        out.push_str(&render_table(
            &format!(
                "Cross-arch throughput — peak IPC & warps-to-saturation (Δ in milli-IPC vs {})",
                base.arch
            ),
            &tp_header_refs,
            &tp_rows,
        ));
    }

    if results.iter().all(|r| !r.mlp.is_empty()) {
        let mut mlp_headers: Vec<String> = vec!["level".into()];
        for r in results {
            mlp_headers.push(format!("lat@{}", r.arch));
        }
        for r in results {
            mlp_headers.push(format!("bw@{}", r.arch));
        }
        for r in results {
            mlp_headers.push(format!("knee@{}", r.arch));
        }
        let mlp_header_refs: Vec<&str> = mlp_headers.iter().map(String::as_str).collect();
        let mlp_rows: Vec<Vec<String>> = base
            .mlp
            .iter()
            .map(|row| {
                let find = |r: &ArchResults<'_>| {
                    r.mlp.iter().find(|m| m.level == row.level)
                };
                let mut cells = vec![row.level.key().to_string()];
                for r in results {
                    cells.push(
                        find(r)
                            .map(|m| m.latency.to_string())
                            .unwrap_or_else(|| "-".to_string()),
                    );
                }
                for r in results {
                    cells.push(
                        find(r)
                            .map(|m| ipc_milli(m.peak_bw_milli))
                            .unwrap_or_else(|| "-".to_string()),
                    );
                }
                for r in results {
                    cells.push(
                        find(r)
                            .map(|m| m.knee_mlp.to_string())
                            .unwrap_or_else(|| "-".to_string()),
                    );
                }
                cells
            })
            .collect();
        out.push_str(&render_table(
            "Cross-arch MLP — anchor latency, bandwidth ceiling (accesses/cycle) & \
             saturation knee ('-' = level absent)",
            &mlp_header_refs,
            &mlp_rows,
        ));
    }

    if results.iter().all(|r| !r.nextgen.is_empty()) {
        let mut ng_headers: Vec<String> = vec!["family".into(), "PTX".into()];
        for r in results {
            ng_headers.push(format!("issue@{}", r.arch));
        }
        for r in results {
            ng_headers.push(format!("done@{}", r.arch));
        }
        let ng_header_refs: Vec<&str> = ng_headers.iter().map(String::as_str).collect();
        let opt = |v: Option<u64>| v.map(|c| c.to_string()).unwrap_or_else(|| "-".to_string());
        let ng_rows: Vec<Vec<String>> = base
            .nextgen
            .iter()
            .map(|row| {
                let find = |r: &ArchResults<'_>| {
                    r.nextgen.iter().find(|m| m.family == row.family)
                };
                let mut cells = vec![row.family.clone(), row.ptx.clone()];
                for r in results {
                    cells.push(opt(find(r).and_then(|m| m.issue_cpi)));
                }
                for r in results {
                    cells.push(opt(find(r).and_then(|m| m.completion)));
                }
                cells
            })
            .collect();
        out.push_str(&render_table(
            "Cross-arch next-gen ISA — issue CPI & completion cycles ('-' = family absent)",
            &ng_header_refs,
            &ng_rows,
        ));
    }
    out
}

/// `repro compare --arch a,b --json`: one entry per Table V row with
/// per-arch CPI and the signed delta vs the first arch, plus the
/// memory-level and WMMA cross-tables.
pub fn compare_json(results: &[ArchResults<'_>]) -> Value {
    assert!(results.len() >= 2, "compare needs at least two architectures");
    let base = &results[0];

    let table5: Vec<Value> = base
        .table5
        .iter()
        .enumerate()
        .map(|(i, row)| {
            let mut cpi = Value::obj();
            for r in results {
                cpi = cpi.set(r.arch, r.table5[i].measured.cpi);
            }
            let mut sass = Value::obj();
            for r in results {
                sass = sass.set(r.arch, r.table5[i].measured.mapping.as_str());
            }
            let mut deltas = Value::obj();
            for r in &results[1..] {
                deltas = deltas.set(
                    r.arch,
                    r.table5[i].measured.cpi as i64 - row.measured.cpi as i64,
                );
            }
            Value::obj()
                .set("name", row.name.as_str())
                .set("cpi", cpi)
                .set("sass", sass)
                .set("delta", deltas)
        })
        .collect();

    let table4: Vec<Value> = base
        .table4
        .iter()
        .enumerate()
        .map(|(i, row)| {
            let mut cpi = Value::obj();
            for r in results {
                cpi = cpi.set(r.arch, r.table4[i].cpi);
            }
            Value::obj().set("level", row.level.name()).set("cpi", cpi)
        })
        .collect();

    let wmma: Vec<Value> = crate::tensor::ALL_DTYPES
        .iter()
        .map(|d| {
            let mut cycles = Value::obj();
            for r in results {
                let entry = r.table3.iter().find(|w| w.dtype_key == d.key());
                cycles = cycles.set(
                    r.arch,
                    entry.map(|w| Value::from(w.cycles)).unwrap_or(Value::Null),
                );
            }
            Value::obj().set("dtype", d.key()).set("cycles", cycles)
        })
        .collect();

    // Cross-arch IPC deltas, aligned by row name (empty sweeps → []).
    let throughput: Vec<Value> = if results.iter().all(|r| !r.throughput.is_empty()) {
        base.throughput
            .iter()
            .map(|row| {
                let mut peak = Value::obj();
                let mut warps = Value::obj();
                let mut deltas = Value::obj();
                for r in results {
                    let entry = r.throughput.iter().find(|t| t.name == row.name);
                    peak = peak.set(
                        r.arch,
                        entry.map(|t| Value::from(t.peak_ipc_milli)).unwrap_or(Value::Null),
                    );
                    warps = warps.set(
                        r.arch,
                        entry.map(|t| Value::from(t.warps_to_peak)).unwrap_or(Value::Null),
                    );
                }
                for r in &results[1..] {
                    let entry = r.throughput.iter().find(|t| t.name == row.name);
                    deltas = deltas.set(
                        r.arch,
                        entry
                            .map(|t| {
                                Value::from(t.peak_ipc_milli as i64 - row.peak_ipc_milli as i64)
                            })
                            .unwrap_or(Value::Null),
                    );
                }
                Value::obj()
                    .set("name", row.name.as_str())
                    .set("kind", row.kind)
                    .set("peak_ipc_milli", peak)
                    .set("warps_to_peak", warps)
                    .set("delta_milli", deltas)
            })
            .collect()
    } else {
        Vec::new()
    };

    // Cross-arch bandwidth/saturation table, aligned by level key; an
    // arch without the level answers null (empty slices → []).
    let mlp: Vec<Value> = if results.iter().all(|r| !r.mlp.is_empty()) {
        base.mlp
            .iter()
            .map(|row| {
                let mut lat = Value::obj();
                let mut bw = Value::obj();
                let mut knee = Value::obj();
                for r in results {
                    let entry = r.mlp.iter().find(|m| m.level == row.level);
                    lat = lat.set(
                        r.arch,
                        entry.map(|m| Value::from(m.latency)).unwrap_or(Value::Null),
                    );
                    bw = bw.set(
                        r.arch,
                        entry.map(|m| Value::from(m.peak_bw_milli)).unwrap_or(Value::Null),
                    );
                    knee = knee.set(
                        r.arch,
                        entry.map(|m| Value::from(m.knee_mlp)).unwrap_or(Value::Null),
                    );
                }
                Value::obj()
                    .set("level", row.level.key())
                    .set("latency", lat)
                    .set("peak_bw_milli", bw)
                    .set("knee_mlp", knee)
            })
            .collect()
    } else {
        Vec::new()
    };

    // Cross-arch next-gen family table, aligned by family key; an arch
    // without the family answers null for every number (empty slices →
    // []).
    let nextgen: Vec<Value> = if results.iter().all(|r| !r.nextgen.is_empty()) {
        base.nextgen
            .iter()
            .map(|row| {
                let mut issue = Value::obj();
                let mut done = Value::obj();
                let mut sass = Value::obj();
                for r in results {
                    let entry = r.nextgen.iter().find(|m| m.family == row.family);
                    let opt = |v: Option<u64>| v.map(Value::from).unwrap_or(Value::Null);
                    issue = issue.set(r.arch, opt(entry.and_then(|m| m.issue_cpi)));
                    done = done.set(r.arch, opt(entry.and_then(|m| m.completion)));
                    sass = sass.set(
                        r.arch,
                        entry
                            .and_then(|m| m.mapping.as_deref())
                            .map(Value::from)
                            .unwrap_or(Value::Null),
                    );
                }
                Value::obj()
                    .set("family", row.family.as_str())
                    .set("ptx", row.ptx.as_str())
                    .set("issue_cpi", issue)
                    .set("completion", done)
                    .set("sass", sass)
            })
            .collect()
    } else {
        Vec::new()
    };

    Value::obj()
        .set(
            "archs",
            Value::Arr(results.iter().map(|r| Value::from(r.arch)).collect()),
        )
        .set("baseline", base.arch)
        .set("rows", base.table5.len())
        .set("table5", Value::Arr(table5))
        .set("table4", Value::Arr(table4))
        .set("wmma", Value::Arr(wmma))
        .set("throughput", Value::Arr(throughput))
        .set("mlp", Value::Arr(mlp))
        .set("nextgen", Value::Arr(nextgen))
}

// ---- machine-readable (`--json`) forms ------------------------------
//
// One builder per experiment so `repro --json table1…table5 | insights`
// and the oracle's model-extraction path share a single JSON shape.

pub fn table1_json(rows: &[Amortization]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| Value::obj().set("n", r.n).set("cpi", r.cpi).set("paper", r.paper_cpi))
            .collect(),
    )
}

pub fn table2_json(rows: &[DepIndep]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("name", r.name.as_str())
                    .set("dep", r.dep_cpi)
                    .set("indep", r.indep_cpi)
                    .set("paper_dep", r.paper_dep)
                    .set("paper_indep", r.paper_indep)
            })
            .collect(),
    )
}

pub fn table3_json(rows: &[WmmaResult]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("dtype", r.dtype_key)
                    .set("cycles", r.cycles)
                    .set("paper", r.paper_cycles)
                    .set("sass", r.sass.as_str())
                    .set("paper_sass", r.paper_sass.as_str())
                    .set("per_sass_cycles", r.per_instruction_cycles)
                    .set("measured_tops", r.throughput.measured_tops)
                    .set("theoretical_tops", r.throughput.theoretical_tops)
                    .set("paper_measured_tops", r.paper_measured_tops)
                    .set("paper_theoretical_tops", r.paper_theoretical_tops)
            })
            .collect(),
    )
}

pub fn table4_json(rows: &[MemResult]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("level", r.level.name())
                    .set("cpi", r.cpi)
                    .set("paper", r.paper)
                    .set("loads", r.loads)
            })
            .collect(),
    )
}

pub fn table5_json(rows: &[RowResult]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("name", r.name.as_str())
                    .set("cpi", r.measured.cpi)
                    .set("paper", r.paper_cycles.as_str())
                    .set("sass", r.measured.mapping.as_str())
                    .set("paper_sass", r.paper_sass.as_str())
                    .set("grade", grade_str(r.cycles_grade))
            })
            .collect(),
    )
}

pub fn throughput_json(rows: &[ThroughputRow]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("name", r.name.as_str())
                    .set("kind", r.kind)
                    .set("n", r.n)
                    .set("cpi_1w", r.cpi_1w)
                    .set("peak_ipc_milli", r.peak_ipc_milli)
                    .set("peak_ipc", r.peak_ipc())
                    .set("warps_to_peak", r.warps_to_peak)
                    .set(
                        "points",
                        Value::Arr(
                            r.points
                                .iter()
                                .map(|p| {
                                    Value::obj()
                                        .set("warps", p.warps)
                                        .set("cycles", p.cycles)
                                        .set("instructions", p.instructions)
                                        .set("ipc_milli", p.ipc_milli)
                                })
                                .collect(),
                        ),
                    )
            })
            .collect(),
    )
}

pub fn mlp_json(rows: &[MlpRow]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("level", r.level.key())
                    .set("latency", r.latency)
                    .set("service", r.service)
                    .set("peak_bw_milli", r.peak_bw_milli)
                    .set("knee_mlp", r.knee_mlp)
                    .set(
                        "points",
                        Value::Arr(
                            r.points
                                .iter()
                                .map(|p| {
                                    Value::obj()
                                        .set("mlp", p.mlp)
                                        .set("per_access_milli", p.per_access_milli)
                                        .set("bw_milli", p.bw_milli())
                                })
                                .collect(),
                        ),
                    )
            })
            .collect(),
    )
}

pub fn gemm_json(rows: &[GemmRow]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("label", r.label.as_str())
                    .set("dtype", r.dtype.as_str())
                    .set("m", r.m)
                    .set("n", r.n)
                    .set("k", r.k)
                    .set("ktiles", r.ktiles)
                    .set("sim_cycles", r.sim_cycles)
                    .set("predicted_cycles", r.predicted_cycles)
                    .set("replayed_sass", r.replayed_sass)
                    .set("match", r.matches)
            })
            .collect(),
    )
}

pub fn fig4_json(f: &Fig4) -> Value {
    Value::obj()
        .set("cpi_32bit", f.cpi_32bit)
        .set("cpi_64bit", f.cpi_64bit)
        .set(
            "sass_32bit",
            Value::Arr(f.sass_32bit.iter().map(|s| Value::from(s.as_str())).collect()),
        )
        .set(
            "sass_64bit",
            Value::Arr(f.sass_64bit.iter().map(|s| Value::from(s.as_str())).collect()),
        )
}

pub fn insights_json(i1: &Insight1, i2: &[SignPair], i3: &[Insight3]) -> Value {
    Value::obj()
        .set(
            "insight1",
            Value::obj()
                .set("mad_mapping", i1.mad_mapping.as_str())
                .set("mixed_cpi", i1.mixed_cpi)
                .set("same_pipe_cpi", i1.same_pipe_cpi),
        )
        .set(
            "insight2",
            Value::Arr(
                i2.iter()
                    .map(|p| {
                        Value::obj()
                            .set("pair", p.base.as_str())
                            .set("unsigned_sass", p.unsigned_mapping.as_str())
                            .set("signed_sass", p.signed_mapping.as_str())
                            .set("unsigned_cpi", p.unsigned_cpi)
                            .set("signed_cpi", p.signed_cpi)
                            .set("differs", p.differs)
                            .set("paper_expects_difference", p.paper_expects_difference)
                    })
                    .collect(),
            ),
        )
        .set(
            "insight3",
            Value::Arr(
                i3.iter()
                    .map(|i| {
                        Value::obj()
                            .set("op", i.op.as_str())
                            .set("mov_init", i.mov_init_mapping.as_str())
                            .set("add_init", i.add_init_mapping.as_str())
                    })
                    .collect(),
            ),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_basic_table() {
        let s = render_table(
            "T",
            &["a", "b"],
            &[vec!["1".into(), "22".into()], vec!["333".into(), "4".into()]],
        );
        assert!(s.contains("== T =="));
        assert!(s.contains("| 333 | 4"));
        assert!(s.lines().filter(|l| l.starts_with('+')).count() >= 3);
    }

    #[test]
    fn grade_strings() {
        assert_eq!(grade_str(MatchGrade::Exact), "exact");
        assert_eq!(grade_str(MatchGrade::Off), "OFF");
    }

    #[test]
    fn throughput_rendering_and_json_share_the_milli_encoding() {
        use crate::microbench::throughput::{ThroughputPoint, ThroughputRow};
        assert_eq!(ipc_milli(500), "0.500");
        assert_eq!(ipc_milli(1000), "1.000");
        assert_eq!(ipc_milli(62), "0.062");

        let rows = vec![ThroughputRow {
            name: "add.u32".into(),
            kind: "table5",
            n: 3,
            cpi_1w: 2,
            points: vec![
                ThroughputPoint { warps: 1, cycles: 10, instructions: 3, ipc_milli: 300 },
                ThroughputPoint { warps: 8, cycles: 50, instructions: 24, ipc_milli: 480 },
            ],
            peak_ipc_milli: 480,
            warps_to_peak: 8,
        }];
        let text = throughput(&rows);
        for needle in ["IPC@1w", "IPC@8w", "0.300", "0.480", "add.u32", "warps@peak"] {
            assert!(text.contains(needle), "{needle} missing:\n{text}");
        }
        let v = throughput_json(&rows);
        let row = v.idx(0).unwrap();
        assert_eq!(row.get("peak_ipc_milli").unwrap().as_u64(), Some(480));
        assert_eq!(row.get("warps_to_peak").unwrap().as_u64(), Some(8));
        assert_eq!(
            row.get("points").unwrap().idx(1).unwrap().get("ipc_milli").unwrap().as_u64(),
            Some(480)
        );
    }

    #[test]
    fn mlp_rendering_and_json_share_the_milli_encoding() {
        use crate::config::MemoryConfig;
        use crate::microbench::mlp::saturation_row;
        use crate::sim::MemLevel;

        let m = MemoryConfig::default();
        let rows = vec![
            saturation_row(MemLevel::Global, 290, &m),
            saturation_row(MemLevel::Shared, 23, &m),
        ];
        let text = mlp(&rows);
        for needle in ["level", "global", "shared", "cyc@1", "cyc@32", "290.000", "knee"] {
            assert!(text.contains(needle), "{needle} missing:\n{text}");
        }

        let v = mlp_json(&rows);
        let row = v.idx(0).unwrap();
        assert_eq!(row.get("level").unwrap().as_str(), Some("global"));
        assert_eq!(row.get("latency").unwrap().as_u64(), Some(290));
        assert_eq!(row.get("service").unwrap().as_u64(), Some(32));
        let p0 = row.get("points").unwrap().idx(0).unwrap();
        assert_eq!(p0.get("mlp").unwrap().as_u64(), Some(1));
        assert_eq!(p0.get("per_access_milli").unwrap().as_u64(), Some(290_000));
        // bandwidth is the reciprocal in milli-accesses/cycle
        assert_eq!(p0.get("bw_milli").unwrap().as_u64(), Some(1_000_000 / 290_000));
    }

    #[test]
    fn gemm_rendering_and_json_agree_on_the_verdict() {
        let rows = vec![GemmRow {
            label: "wmma[f16_f16 m16n16k16]".into(),
            dtype: "f16_f16".into(),
            m: 16,
            n: 16,
            k: 16,
            ktiles: 4,
            sim_cycles: 420,
            predicted_cycles: 420,
            matches: true,
            replayed_sass: 96,
        }];
        let text = gemm(&rows);
        for needle in ["16x16x16", "420", "exact", "1/1 exact", "wmma[f16_f16 m16n16k16]"] {
            assert!(text.contains(needle), "{needle} missing:\n{text}");
        }
        let v = gemm_json(&rows);
        let row = v.idx(0).unwrap();
        assert_eq!(row.get("sim_cycles").unwrap().as_u64(), Some(420));
        assert_eq!(row.get("predicted_cycles").unwrap().as_u64(), Some(420));
        assert_eq!(row.get("match").unwrap().as_bool(), Some(true));
        assert_eq!(row.get("replayed_sass").unwrap().as_u64(), Some(96));
    }

    #[test]
    fn json_forms_carry_the_table_fields() {
        let t1 = table1_json(&[Amortization { n: 1, cpi: 5, paper_cpi: 5 }]);
        let row = t1.idx(0).unwrap();
        assert_eq!(row.get("cpi").unwrap().as_u64(), Some(5));
        assert_eq!(row.get("paper").unwrap().as_u64(), Some(5));

        let t2 = table2_json(&[DepIndep {
            name: "add.u32".into(),
            dep_cpi: 4,
            indep_cpi: 2,
            paper_dep: 4,
            paper_indep: 2,
        }]);
        let row = t2.idx(0).unwrap();
        assert_eq!(row.get("name").unwrap().as_str(), Some("add.u32"));
        assert_eq!(row.get("dep").unwrap().as_u64(), Some(4));

        let f4 = fig4_json(&Fig4 {
            cpi_32bit: 13,
            cpi_64bit: 2,
            sass_32bit: vec!["DEPBAR".into()],
            sass_64bit: vec![],
        });
        assert_eq!(f4.get("cpi_32bit").unwrap().as_u64(), Some(13));
        assert_eq!(f4.get("sass_32bit").unwrap().idx(0).unwrap().as_str(), Some("DEPBAR"));
    }
}
