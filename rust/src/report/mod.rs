//! Table renderers: print each experiment in the paper's row format next
//! to the published values, plus a shape-match summary for EXPERIMENTS.md.

use crate::microbench::alu::{Amortization, DepIndep, RowResult};
use crate::microbench::insights::{Fig4, Insight1, Insight3, SignPair};
use crate::microbench::memory::MemResult;
use crate::microbench::wmma::WmmaResult;
use crate::microbench::MatchGrade;
use std::fmt::Write;

fn hr(out: &mut String, widths: &[usize]) {
    for w in widths {
        let _ = write!(out, "+{}", "-".repeat(w + 2));
    }
    out.push_str("+\n");
}

fn row_line(out: &mut String, widths: &[usize], cells: &[String]) {
    for (w, c) in widths.iter().zip(cells) {
        let _ = write!(out, "| {c:<w$} ");
    }
    out.push_str("|\n");
}

/// Generic table printer.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "\n== {title} ==");
    hr(&mut out, &widths);
    row_line(&mut out, &widths, &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    hr(&mut out, &widths);
    for r in rows {
        row_line(&mut out, &widths, r);
    }
    hr(&mut out, &widths);
    out
}

pub fn grade_str(g: MatchGrade) -> &'static str {
    match g {
        MatchGrade::Exact => "exact",
        MatchGrade::Close => "close",
        MatchGrade::Off => "OFF",
    }
}

pub fn table1(rows: &[Amortization]) -> String {
    render_table(
        "Table I — CPI vs #instructions (add.u32, cold pipe)",
        &["# instrs", "CPI (measured)", "CPI (paper)"],
        &rows
            .iter()
            .map(|r| vec![r.n.to_string(), r.cpi.to_string(), r.paper_cpi.to_string()])
            .collect::<Vec<_>>(),
    )
}

pub fn table2(rows: &[DepIndep]) -> String {
    render_table(
        "Table II — dependent vs independent CPI",
        &["instr", "dep", "dep(paper)", "indep", "indep(paper)"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.dep_cpi.to_string(),
                    r.paper_dep.to_string(),
                    r.indep_cpi.to_string(),
                    r.paper_indep.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

pub fn table3(rows: &[WmmaResult]) -> String {
    render_table(
        "Table III — tensor-core latency & throughput",
        &[
            "dtype",
            "cycles",
            "paper",
            "SASS (measured)",
            "SASS (paper)",
            "TOPS meas-theo",
            "paper meas-theo",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.dtype_key.to_string(),
                    r.cycles.to_string(),
                    r.paper_cycles.to_string(),
                    r.sass.clone(),
                    r.paper_sass.clone(),
                    format!(
                        "{:.0}-{:.1}",
                        r.throughput.measured_tops, r.throughput.theoretical_tops
                    ),
                    format!("{:.0}-{:.1}", r.paper_measured_tops, r.paper_theoretical_tops),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

pub fn table4(rows: &[MemResult]) -> String {
    render_table(
        "Table IV — memory access latencies",
        &["Memory type", "CPI (measured)", "CPI (paper)"],
        &rows
            .iter()
            .map(|r| vec![r.level.name().to_string(), r.cpi.to_string(), r.paper.to_string()])
            .collect::<Vec<_>>(),
    )
}

pub fn table5(rows: &[RowResult]) -> String {
    let mut body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.measured.mapping.clone(),
                r.paper_sass.clone(),
                r.measured.cpi.to_string(),
                r.paper_cycles.clone(),
                grade_str(r.cycles_grade).to_string(),
            ]
        })
        .collect();
    let exact = rows.iter().filter(|r| r.cycles_grade == MatchGrade::Exact).count();
    let close = rows.iter().filter(|r| r.cycles_grade == MatchGrade::Close).count();
    body.push(vec![
        format!("[{} rows]", rows.len()),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{exact} exact / {close} close"),
    ]);
    render_table(
        "Table V — PTX→SASS mapping and clock cycles",
        &["PTX", "SASS (measured)", "SASS (paper)", "cyc", "paper", "grade"],
        &body,
    )
}

pub fn fig4(f: &Fig4) -> String {
    render_table(
        "Fig. 4 — clock register width",
        &["variant", "CPI", "paper"],
        &[
            vec!["32-bit clocks (barrier)".into(), f.cpi_32bit.to_string(), "13".into()],
            vec!["64-bit clocks (CS2R)".into(), f.cpi_64bit.to_string(), "2".into()],
        ],
    )
}

pub fn insights(i1: &Insight1, i2: &[SignPair], i3: &[Insight3]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "\n== Insight 1 — integer mad on the FP pipe ==\n  mad.lo.u32 -> {} ; mixed-pipe CPI {} vs same-pipe {}",
        i1.mad_mapping, i1.mixed_cpi, i1.same_pipe_cpi
    );
    out.push_str(&render_table(
        "Insight 2 — signed vs unsigned",
        &["pair", "unsigned SASS", "signed SASS", "differs", "paper"],
        &i2.iter()
            .map(|p| {
                vec![
                    p.base.clone(),
                    p.unsigned_mapping.clone(),
                    p.signed_mapping.clone(),
                    p.differs.to_string(),
                    p.paper_expects_difference.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    ));
    out.push_str(&render_table(
        "Insight 3 — init style changes the mapping",
        &["op", "mov-init", "add-init"],
        &i3.iter()
            .map(|i| vec![i.op.clone(), i.mov_init_mapping.clone(), i.add_init_mapping.clone()])
            .collect::<Vec<_>>(),
    ));
    out
}

// ---- machine-readable (`--json`) forms ------------------------------
//
// One builder per experiment so `repro --json table1…table5 | insights`
// and the oracle's model-extraction path share a single JSON shape.

use crate::util::json::Value;

pub fn table1_json(rows: &[Amortization]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| Value::obj().set("n", r.n).set("cpi", r.cpi).set("paper", r.paper_cpi))
            .collect(),
    )
}

pub fn table2_json(rows: &[DepIndep]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("name", r.name.as_str())
                    .set("dep", r.dep_cpi)
                    .set("indep", r.indep_cpi)
                    .set("paper_dep", r.paper_dep)
                    .set("paper_indep", r.paper_indep)
            })
            .collect(),
    )
}

pub fn table3_json(rows: &[WmmaResult]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("dtype", r.dtype_key)
                    .set("cycles", r.cycles)
                    .set("paper", r.paper_cycles)
                    .set("sass", r.sass.as_str())
                    .set("paper_sass", r.paper_sass.as_str())
                    .set("per_sass_cycles", r.per_instruction_cycles)
                    .set("measured_tops", r.throughput.measured_tops)
                    .set("theoretical_tops", r.throughput.theoretical_tops)
                    .set("paper_measured_tops", r.paper_measured_tops)
                    .set("paper_theoretical_tops", r.paper_theoretical_tops)
            })
            .collect(),
    )
}

pub fn table4_json(rows: &[MemResult]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("level", r.level.name())
                    .set("cpi", r.cpi)
                    .set("paper", r.paper)
                    .set("loads", r.loads)
            })
            .collect(),
    )
}

pub fn table5_json(rows: &[RowResult]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                Value::obj()
                    .set("name", r.name.as_str())
                    .set("cpi", r.measured.cpi)
                    .set("paper", r.paper_cycles.as_str())
                    .set("sass", r.measured.mapping.as_str())
                    .set("paper_sass", r.paper_sass.as_str())
                    .set("grade", grade_str(r.cycles_grade))
            })
            .collect(),
    )
}

pub fn fig4_json(f: &Fig4) -> Value {
    Value::obj()
        .set("cpi_32bit", f.cpi_32bit)
        .set("cpi_64bit", f.cpi_64bit)
        .set(
            "sass_32bit",
            Value::Arr(f.sass_32bit.iter().map(|s| Value::from(s.as_str())).collect()),
        )
        .set(
            "sass_64bit",
            Value::Arr(f.sass_64bit.iter().map(|s| Value::from(s.as_str())).collect()),
        )
}

pub fn insights_json(i1: &Insight1, i2: &[SignPair], i3: &[Insight3]) -> Value {
    Value::obj()
        .set(
            "insight1",
            Value::obj()
                .set("mad_mapping", i1.mad_mapping.as_str())
                .set("mixed_cpi", i1.mixed_cpi)
                .set("same_pipe_cpi", i1.same_pipe_cpi),
        )
        .set(
            "insight2",
            Value::Arr(
                i2.iter()
                    .map(|p| {
                        Value::obj()
                            .set("pair", p.base.as_str())
                            .set("unsigned_sass", p.unsigned_mapping.as_str())
                            .set("signed_sass", p.signed_mapping.as_str())
                            .set("unsigned_cpi", p.unsigned_cpi)
                            .set("signed_cpi", p.signed_cpi)
                            .set("differs", p.differs)
                            .set("paper_expects_difference", p.paper_expects_difference)
                    })
                    .collect(),
            ),
        )
        .set(
            "insight3",
            Value::Arr(
                i3.iter()
                    .map(|i| {
                        Value::obj()
                            .set("op", i.op.as_str())
                            .set("mov_init", i.mov_init_mapping.as_str())
                            .set("add_init", i.add_init_mapping.as_str())
                    })
                    .collect(),
            ),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_basic_table() {
        let s = render_table(
            "T",
            &["a", "b"],
            &[vec!["1".into(), "22".into()], vec!["333".into(), "4".into()]],
        );
        assert!(s.contains("== T =="));
        assert!(s.contains("| 333 | 4"));
        assert!(s.lines().filter(|l| l.starts_with('+')).count() >= 3);
    }

    #[test]
    fn grade_strings() {
        assert_eq!(grade_str(MatchGrade::Exact), "exact");
        assert_eq!(grade_str(MatchGrade::Off), "OFF");
    }

    #[test]
    fn json_forms_carry_the_table_fields() {
        let t1 = table1_json(&[Amortization { n: 1, cpi: 5, paper_cpi: 5 }]);
        let row = t1.idx(0).unwrap();
        assert_eq!(row.get("cpi").unwrap().as_u64(), Some(5));
        assert_eq!(row.get("paper").unwrap().as_u64(), Some(5));

        let t2 = table2_json(&[DepIndep {
            name: "add.u32".into(),
            dep_cpi: 4,
            indep_cpi: 2,
            paper_dep: 4,
            paper_indep: 2,
        }]);
        let row = t2.idx(0).unwrap();
        assert_eq!(row.get("name").unwrap().as_str(), Some("add.u32"));
        assert_eq!(row.get("dep").unwrap().as_u64(), Some(4));

        let f4 = fig4_json(&Fig4 {
            cpi_32bit: 13,
            cpi_64bit: 2,
            sass_32bit: vec!["DEPBAR".into()],
            sass_64bit: vec![],
        });
        assert_eq!(f4.get("cpi_32bit").unwrap().as_u64(), Some(13));
        assert_eq!(f4.get("sass_32bit").unwrap().idx(0).unwrap().as_str(), Some("DEPBAR"));
    }
}
