//! Tensor-core throughput model (Table III's "Measured-theoretical"
//! column).
//!
//! The paper's numbers are whole-GPU peak rates in the whitepaper's units
//! (TFLOPS / TOPS — printed "GB/s" in the paper):
//!
//! ```text
//! theoretical = 2 MACs × tile_MACs / per_inst_cycles
//!             × TCs_per_SM × SMs × clock
//! f16 : 2·2048/8 ·4·108·1.41e9 = 311.7 T → paper "312"
//! tf32: 2·512/4  ·4·108·1.41e9 = 155.9 T → paper "156"
//! f64 : 2·256/16 ·4·108·1.41e9 =  19.5 T → paper "19.5"
//! u8  : 2·2048/4 ·4·108·1.41e9 = 623.5 T → paper "624"
//! u4  : dual-rail int4 (2 tiles in flight) = 1247 T → paper "1248"
//! ```
//!
//! "Measured" comes from streaming N independent tiles through the TC
//! pipe model: pipeline startup plus a per-dtype operand-delivery stall
//! (registers feed the TC through the same ports the MOVM path uses;
//! tf32's 4-byte operands stall the most — the paper measures 132 of
//! 156).  Stall cycles are calibrated; the *mechanism* (efficiency =
//! issue-limited cycles / total cycles) is the model.

use super::WmmaDtype;
use crate::config::AmpereConfig;

/// Throughput result for one dtype.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub dtype_key: &'static str,
    /// Simulated achieved rate, in the paper's units (T-ops/s).
    pub measured_tops: f64,
    /// Whitepaper-peak rate.
    pub theoretical_tops: f64,
}

impl Throughput {
    pub fn efficiency(&self) -> f64 {
        self.measured_tops / self.theoretical_tops
    }
}

/// MACs retired by one SASS MMA instruction.
pub fn tile_macs(dtype: WmmaDtype) -> u64 {
    let (tm, tn, tk) = dtype.sass_tile();
    tm as u64 * tn as u64 * tk as u64
}

/// int4 runs two tiles in flight per issue slot (dual-rail datapath) —
/// how 1248 TOPS comes out of the same 4-cycle IMMA.8832 issue.
fn rails(dtype: WmmaDtype) -> u64 {
    if dtype == WmmaDtype::U4S32 {
        2
    } else {
        1
    }
}

/// Whitepaper-peak rate for the dtype.
pub fn theoretical_tops(dtype: WmmaDtype, cfg: &AmpereConfig) -> f64 {
    let ops_per_cycle_per_tc =
        2.0 * (tile_macs(dtype) * rails(dtype)) as f64 / dtype.per_instruction_cycles() as f64;
    ops_per_cycle_per_tc
        * cfg.tensor.cores_per_sm as f64
        * cfg.sm_count as f64
        * cfg.tensor.clock_hz
        / 1e12
}

/// Operand-delivery stall per SASS instruction, in 1/16ths of a cycle
/// (calibrated to the paper's measured column; the tf32 path pays the
/// most because its operands are 4-byte and bypass the MOVM-optimised
/// half-precision register path).
fn operand_stall_sixteenths(dtype: WmmaDtype) -> u64 {
    match dtype {
        WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32 => 0,
        WmmaDtype::Tf32F32 => 11, // 132/156 measured
        WmmaDtype::F64F64 => 4,   // 19/19.5
        WmmaDtype::U8S32 => 3,    // 594/624
        WmmaDtype::U4S32 => 0,    // 1229/1248 (startup-dominated)
    }
}

/// Simulate a stream of `tiles` independent SASS MMA instructions through
/// the TC pipe: total cycles = startup + Σ(occ + stall).  Returns total
/// cycles (u64) and ideal issue-limited cycles.
pub fn stream_cycles(dtype: WmmaDtype, tiles: u64, cfg: &AmpereConfig) -> (u64, u64) {
    let occ16 = dtype.per_instruction_cycles() * 16;
    let stall16 = operand_stall_sixteenths(dtype);
    let total16 = cfg.tensor.startup_cycles * 16 + tiles * (occ16 + stall16);
    let ideal16 = tiles * occ16;
    (total16 / 16, ideal16 / 16)
}

/// Full throughput measurement for one dtype: stream `tiles` tiles, scale
/// the whitepaper peak by achieved/ideal cycles.
pub fn throughput(dtype: WmmaDtype, tiles: u64, cfg: &AmpereConfig) -> Throughput {
    let theo = theoretical_tops(dtype, cfg);
    let (total, ideal) = stream_cycles(dtype, tiles, cfg);
    Throughput {
        dtype_key: dtype.key(),
        measured_tops: theo * ideal as f64 / total as f64,
        theoretical_tops: theo,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ALL_DTYPES;

    #[test]
    fn theoretical_matches_whitepaper() {
        let cfg = AmpereConfig::a100();
        let expect = [
            (WmmaDtype::F16F16, 312.0),
            (WmmaDtype::F16F32, 312.0),
            (WmmaDtype::Bf16F32, 312.0),
            (WmmaDtype::Tf32F32, 156.0),
            (WmmaDtype::F64F64, 19.5),
            (WmmaDtype::U8S32, 624.0),
            (WmmaDtype::U4S32, 1248.0),
        ];
        for (d, t) in expect {
            let got = theoretical_tops(d, &cfg);
            assert!(
                (got - t).abs() / t < 0.01,
                "{d:?}: got {got:.1}, whitepaper {t}"
            );
        }
    }

    #[test]
    fn measured_matches_paper_bands() {
        // Table III measured column: 311, 310, 310, 132, 19, 594, 1229.
        let cfg = AmpereConfig::a100();
        let expect = [
            (WmmaDtype::F16F16, 311.0, 5.0),
            (WmmaDtype::Bf16F32, 310.0, 5.0),
            (WmmaDtype::Tf32F32, 132.0, 8.0),
            (WmmaDtype::F64F64, 19.0, 0.6),
            (WmmaDtype::U8S32, 594.0, 15.0),
            (WmmaDtype::U4S32, 1229.0, 25.0),
        ];
        for (d, want, tol) in expect {
            let t = throughput(d, 4096, &cfg);
            assert!(
                (t.measured_tops - want).abs() < tol,
                "{d:?}: measured {:.1}, paper {want}",
                t.measured_tops
            );
            assert!(t.measured_tops < t.theoretical_tops);
        }
    }

    #[test]
    fn efficiency_ordering_matches_paper() {
        // fp16 is near-peak (0.997); tf32 is the worst (0.846).
        let cfg = AmpereConfig::a100();
        let eff = |d| throughput(d, 4096, &cfg).efficiency();
        assert!(eff(WmmaDtype::F16F16) > 0.99);
        assert!(eff(WmmaDtype::Tf32F32) < 0.90);
        for d in ALL_DTYPES {
            let e = eff(d);
            assert!(e > 0.5 && e < 1.0, "{d:?}: {e}");
        }
    }

    #[test]
    fn startup_dominates_short_streams() {
        let cfg = AmpereConfig::a100();
        let short = throughput(WmmaDtype::F16F16, 4, &cfg);
        let long = throughput(WmmaDtype::F16F16, 4096, &cfg);
        assert!(short.efficiency() < long.efficiency());
    }
}
