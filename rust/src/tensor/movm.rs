//! MOVM.16.MT88 layout rules (paper §V-C, last paragraph).
//!
//! MOVM moves a matrix *with a transpose*.  Which fragments need it is a
//! pure function of the A/B storage layouts declared in the WMMA PTX:
//!
//! * A row-major, B row-major  → transpose **B** (multiply rows of A by
//!   columns of B; B arrives row-major so it must be flipped);
//! * A col-major, B col-major  → transpose **A and C before** execution
//!   and **C after** (the datapath is row×col native);
//! * A row-major, B col-major  → **no MOVM at all**;
//! * A col-major, B row-major  → both operands are wrong-way: transpose
//!   A and B (the paper doesn't tabulate this case; it follows from the
//!   same rule).


/// Which fragments get a MOVM transpose for a given layout pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MovmPlan {
    pub transpose_a: bool,
    pub transpose_b: bool,
    /// C transposed before the MMA.
    pub transpose_c_in: bool,
    /// C transposed back after the MMA (store path).
    pub transpose_c_out: bool,
}

impl MovmPlan {
    /// Total MOVM instructions the full load→mma→store sequence issues.
    pub fn movm_count(&self) -> u32 {
        self.transpose_a as u32
            + self.transpose_b as u32
            + self.transpose_c_in as u32
            + self.transpose_c_out as u32
    }
}

/// The rule table.  `a_row`/`b_row`: fragment is row-major.
pub fn movm_plan(a_row: bool, b_row: bool) -> MovmPlan {
    match (a_row, b_row) {
        // row × row: flip B.
        (true, true) => MovmPlan {
            transpose_a: false,
            transpose_b: true,
            transpose_c_in: false,
            transpose_c_out: false,
        },
        // col × col: flip A and C in, C back out.
        (false, false) => MovmPlan {
            transpose_a: true,
            transpose_b: false,
            transpose_c_in: true,
            transpose_c_out: true,
        },
        // row × col: native — no MOVM in the trace.
        (true, false) => MovmPlan {
            transpose_a: false,
            transpose_b: false,
            transpose_c_in: false,
            transpose_c_out: false,
        },
        // col × row: both operands flipped.
        (false, true) => MovmPlan {
            transpose_a: true,
            transpose_b: true,
            transpose_c_in: false,
            transpose_c_out: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_row_transposes_b_only() {
        let p = movm_plan(true, true);
        assert!(!p.transpose_a && p.transpose_b);
        assert!(!p.transpose_c_in && !p.transpose_c_out);
        assert_eq!(p.movm_count(), 1);
    }

    #[test]
    fn col_col_transposes_a_and_c_both_ways() {
        let p = movm_plan(false, false);
        assert!(p.transpose_a && !p.transpose_b);
        assert!(p.transpose_c_in && p.transpose_c_out);
        assert_eq!(p.movm_count(), 3);
    }

    #[test]
    fn row_col_needs_no_movm() {
        // Paper: "if A is a row-major and B is a column-major, the MOVM
        // instruction does not exist in the trace."
        assert_eq!(movm_plan(true, false).movm_count(), 0);
    }

    #[test]
    fn col_row_flips_both_operands() {
        let p = movm_plan(false, true);
        assert!(p.transpose_a && p.transpose_b);
        assert_eq!(p.movm_count(), 2);
    }
}
