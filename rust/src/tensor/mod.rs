//! Tensor-core (WMMA) model — Table III and Fig. 6.
//!
//! Three concerns, mirrored 1:1 with the python layer
//! (`python/compile/kernels/wmma.py` carries the same decomposition
//! arithmetic — `sass_grid`/`effective_tile` — and pytest pins them equal):
//!
//! 1. **Decomposition**: one WMMA PTX instruction becomes N shape-limited
//!    SASS instructions (`2*HMMA.16816`, `4*HMMA.1684`, `1*DMMA.884`, …).
//! 2. **Timing**: per-SASS-instruction cycles from Table III
//!    (8/8/8/4/16/4/4), occupancy-limited and pipelined, so the dependent
//!    WMMA chain of the Fig. 5 microbenchmark measures N × cycles.
//! 3. **Layout movement**: the MOVM.16.MT88 transpose rules — row×row
//!    transposes B, col×col transposes A and C (in *and* out), row×col
//!    needs no MOVM.

pub mod movm;
pub mod throughput;

use crate::ptx::ast::WmmaOp;
use crate::ptx::{PtxInstruction, PtxType, Reg};
use crate::sass::{Effect, SassClass, SassInstr};
use crate::translate::Translator;

pub use movm::{movm_plan, MovmPlan};
pub use throughput::{throughput, Throughput};

/// WMMA dtype configuration key (same names as the python layer and the
/// AOT artifact files `artifacts/wmma_<key>.hlo.txt`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WmmaDtype {
    F16F16,
    F16F32,
    Bf16F32,
    Tf32F32,
    F64F64,
    U8S32,
    U4S32,
}

pub const ALL_DTYPES: [WmmaDtype; 7] = [
    WmmaDtype::F16F16,
    WmmaDtype::F16F32,
    WmmaDtype::Bf16F32,
    WmmaDtype::Tf32F32,
    WmmaDtype::F64F64,
    WmmaDtype::U8S32,
    WmmaDtype::U4S32,
];

impl WmmaDtype {
    pub fn key(self) -> &'static str {
        match self {
            WmmaDtype::F16F16 => "f16_f16",
            WmmaDtype::F16F32 => "f16_f32",
            WmmaDtype::Bf16F32 => "bf16_f32",
            WmmaDtype::Tf32F32 => "tf32_f32",
            WmmaDtype::F64F64 => "f64_f64",
            WmmaDtype::U8S32 => "u8_s32",
            WmmaDtype::U4S32 => "u4_s32",
        }
    }

    /// From the PTX fragment types [d, a, b, c] (Table III's PTX column).
    pub fn from_fragment_types(t: &[PtxType; 4]) -> Option<WmmaDtype> {
        Some(match (t[1], t[0]) {
            (PtxType::F16, PtxType::F16) => WmmaDtype::F16F16,
            (PtxType::F16, PtxType::F32) => WmmaDtype::F16F32,
            (PtxType::Bf16, _) => WmmaDtype::Bf16F32,
            (PtxType::Tf32, _) => WmmaDtype::Tf32F32,
            (PtxType::F64, _) => WmmaDtype::F64F64,
            (PtxType::U8, _) => WmmaDtype::U8S32,
            (PtxType::U4, _) => WmmaDtype::U4S32,
            _ => return None,
        })
    }

    /// Primary PTX shape (M, N, K) — Table III column 1.
    pub fn primary_shape(self) -> (u32, u32, u32) {
        match self {
            WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32 | WmmaDtype::U8S32 => {
                (16, 16, 16)
            }
            WmmaDtype::Tf32F32 => (16, 16, 8),
            WmmaDtype::F64F64 => (8, 8, 4),
            WmmaDtype::U4S32 => (8, 8, 32),
        }
    }

    /// All PTX shapes the dtype supports.
    pub fn supported_shapes(self) -> Vec<(u32, u32, u32)> {
        match self {
            WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32 | WmmaDtype::U8S32 => {
                vec![(16, 16, 16), (8, 32, 16), (32, 8, 16)]
            }
            WmmaDtype::Tf32F32 => vec![(16, 16, 8)],
            WmmaDtype::F64F64 => vec![(8, 8, 4)],
            WmmaDtype::U4S32 => vec![(8, 8, 32)],
        }
    }

    /// The SASS tile the hardware iterates with (Table III's SASS column).
    pub fn sass_tile(self) -> (u32, u32, u32) {
        match self {
            WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32 | WmmaDtype::U8S32 => {
                (16, 8, 16)
            }
            WmmaDtype::Tf32F32 => (16, 8, 4),
            WmmaDtype::F64F64 => (8, 8, 4),
            WmmaDtype::U4S32 => (8, 8, 32),
        }
    }

    /// SASS mnemonic (Table III).
    pub fn sass_mnemonic(self) -> &'static str {
        match self {
            WmmaDtype::F16F16 => "HMMA.16816.F16",
            WmmaDtype::F16F32 => "HMMA.16816.F32",
            WmmaDtype::Bf16F32 => "HMMA.16816.F32.BF16",
            WmmaDtype::Tf32F32 => "HMMA.1684.F32.TF32",
            WmmaDtype::F64F64 => "DMMA.884",
            WmmaDtype::U8S32 => "IMMA.16816.U8.U8",
            WmmaDtype::U4S32 => "IMMA.8832.U4.U4",
        }
    }

    /// Cycles per SASS instruction (Table III: "each inst. is N cycles").
    pub fn per_instruction_cycles(self) -> u64 {
        match self {
            WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32 => 8,
            WmmaDtype::Tf32F32 => 4,
            WmmaDtype::F64F64 => 16,
            WmmaDtype::U8S32 => 4,
            WmmaDtype::U4S32 => 4,
        }
    }

    /// Input-element bits.
    pub fn input_bits(self) -> u32 {
        match self {
            WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32 => 16,
            WmmaDtype::Tf32F32 => 32,
            WmmaDtype::F64F64 => 64,
            WmmaDtype::U8S32 => 8,
            WmmaDtype::U4S32 => 4,
        }
    }

    /// Is the input a half-precision float (MOVM applies — paper §V-C:
    /// "for all half floating precision (fp16 and bf16) inputs, SASS
    /// instruction MOVM.16.MT88 is used").
    pub fn uses_movm(self) -> bool {
        matches!(self, WmmaDtype::F16F16 | WmmaDtype::F16F32 | WmmaDtype::Bf16F32)
    }
}

/// The SASS tile re-shaped for wide/tall PTX shapes: a SASS MMA always
/// retires the same MAC count for a dtype, so m8n32k16 decomposes as two
/// 8×16×16 tiles etc. (why the paper finds Ampere latency
/// shape-independent within a dtype).  Mirrors python `effective_tile`.
pub fn effective_tile(dtype: WmmaDtype, shape: (u32, u32, u32)) -> (u32, u32, u32) {
    let (m, _n, _k) = shape;
    let (tm, tn, tk) = dtype.sass_tile();
    let macs = tm as u64 * tn as u64 * tk as u64;
    let tm = m.min(tm);
    let tn = (macs / (tm as u64 * tk as u64)) as u32;
    (tm, tn.min(shape.1), tk)
}

/// Number of SASS MMA instructions one WMMA PTX instruction becomes.
pub fn sass_instruction_count(dtype: WmmaDtype, shape: (u32, u32, u32)) -> u32 {
    let (m, n, k) = shape;
    let (tm, tn, tk) = effective_tile(dtype, shape);
    assert!(
        m % tm == 0 && n % tn == 0 && k % tk == 0,
        "unsupported WMMA shape {shape:?} for {dtype:?}"
    );
    (m / tm) * (n / tn) * (k / tk)
}

/// Latency of one dependent WMMA PTX instruction = SASS count × per-SASS
/// cycles (Table III's "Cycles" column: 16/16/16/16/16/8/4).
pub fn ptx_latency(dtype: WmmaDtype, shape: (u32, u32, u32)) -> u64 {
    sass_instruction_count(dtype, shape) as u64 * dtype.per_instruction_cycles()
}

/// Translate a WMMA PTX instruction into SASS (called from
/// `translate::rules`).
pub fn translate_wmma(
    tr: &mut Translator,
    ins: &PtxInstruction,
    op: WmmaOp,
    dst: Option<Reg>,
    srcs: &[Reg],
) -> Result<Vec<SassInstr>, String> {
    match op {
        WmmaOp::Mma => {
            let types = ins.wmma_types.ok_or("wmma.mma without fragment types")?;
            let dtype = WmmaDtype::from_fragment_types(&types)
                .ok_or_else(|| format!("unsupported wmma fragment types {types:?}"))?;
            let shape = ins.wmma_shape.ok_or("wmma.mma without shape")?;
            let count = sass_instruction_count(dtype, shape);
            let cyc = dtype.per_instruction_cycles();
            let mut out = Vec::with_capacity(count as usize + 1);
            for i in 0..count {
                let mut s = SassInstr::new(dtype.sass_mnemonic(), SassClass::Mma)
                    .occ(cyc)
                    .lat(cyc)
                    .effect(Effect::MmaTile);
                // All tiles read the fragment sources; the last writes the
                // accumulator (EvalPtx applies the functional result).
                for r in srcs.iter().take(3) {
                    s = s.src(*r);
                }
                if i + 1 == count {
                    s.dst = dst;
                    s.effect = Effect::EvalPtx;
                } else {
                    s.dst = Some(tr.temp());
                }
                out.push(s);
            }
            // Fig. 6: a lone TC instruction shows a trailing NOP
            // (warp-sync) in the dynamic SASS.
            if ins.mods.sync {
                out.push(SassInstr::new("NOP", SassClass::Control).effect(Effect::WarpSync));
            }
            Ok(out)
        }
        WmmaOp::LoadA | WmmaOp::LoadB | WmmaOp::LoadC => {
            let types = ins.wmma_types;
            let dtype = types
                .as_ref()
                .and_then(WmmaDtype::from_fragment_types)
                .or_else(|| match ins.ty {
                    Some(PtxType::F16) => Some(WmmaDtype::F16F32),
                    Some(PtxType::Bf16) => Some(WmmaDtype::Bf16F32),
                    // f32/s32 fragments are accumulators (or tf32 inputs):
                    // either way no half-precision MOVM path applies.
                    Some(PtxType::Tf32) | Some(PtxType::F32) => Some(WmmaDtype::Tf32F32),
                    Some(PtxType::F64) => Some(WmmaDtype::F64F64),
                    Some(PtxType::U8) => Some(WmmaDtype::U8S32),
                    Some(PtxType::U4) | Some(PtxType::S32) => Some(WmmaDtype::U4S32),
                    _ => None,
                })
                .ok_or("wmma.load without dtype")?;
            let layout = ins.wmma_layout.unwrap_or((true, true));
            let plan = movm_plan(layout.0, layout.1);
            let mut out = Vec::new();
            let mut ld = SassInstr::new("LDG.E", SassClass::Memory).effect(Effect::Load);
            if let Some(d) = dst {
                ld.dst = Some(d);
            }
            for r in srcs.iter().take(2) {
                ld = ld.src(*r);
            }
            out.push(ld);
            let needs_movm = dtype.uses_movm()
                && match op {
                    WmmaOp::LoadA => plan.transpose_a,
                    WmmaOp::LoadB => plan.transpose_b,
                    WmmaOp::LoadC => plan.transpose_c_in,
                    _ => false,
                };
            if needs_movm {
                let mut mv =
                    SassInstr::new("MOVM.16.MT88", SassClass::Movm).effect(Effect::Movm);
                if let Some(d) = dst {
                    mv = mv.src(d);
                    mv.dst = Some(d);
                }
                out.push(mv);
            }
            Ok(out)
        }
        WmmaOp::Store => {
            let layout = ins.wmma_layout.unwrap_or((true, true));
            let plan = movm_plan(layout.0, layout.1);
            let mut out = Vec::new();
            if plan.transpose_c_out {
                let mut mv = SassInstr::new("MOVM.16.MT88", SassClass::Movm).effect(Effect::Movm);
                if let Some(r) = srcs.first() {
                    mv = mv.src(*r);
                    mv.dst = Some(tr.temp());
                }
                out.push(mv);
            }
            let mut st = SassInstr::new("STG.E", SassClass::Memory).effect(Effect::Store);
            for r in srcs.iter().take(3) {
                st = st.src(*r);
            }
            out.push(st);
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_sass_counts() {
        // Table III "Instructions" column: 2/2/2/4/1/2/1.
        for (d, n) in [
            (WmmaDtype::F16F16, 2),
            (WmmaDtype::F16F32, 2),
            (WmmaDtype::Bf16F32, 2),
            (WmmaDtype::Tf32F32, 4),
            (WmmaDtype::F64F64, 1),
            (WmmaDtype::U8S32, 2),
            (WmmaDtype::U4S32, 1),
        ] {
            assert_eq!(sass_instruction_count(d, d.primary_shape()), n, "{d:?}");
        }
    }

    #[test]
    fn table3_ptx_latencies() {
        // Table III "Cycles" column: 16 for all floats, 8 for u8, 4 for u4.
        for (d, c) in [
            (WmmaDtype::F16F16, 16),
            (WmmaDtype::F16F32, 16),
            (WmmaDtype::Bf16F32, 16),
            (WmmaDtype::Tf32F32, 16),
            (WmmaDtype::F64F64, 16),
            (WmmaDtype::U8S32, 8),
            (WmmaDtype::U4S32, 4),
        ] {
            assert_eq!(ptx_latency(d, d.primary_shape()), c, "{d:?}");
        }
    }

    #[test]
    fn shape_independent_latency_within_dtype() {
        // Paper §V-C: different shapes of the same dtype → same latency.
        for d in ALL_DTYPES {
            let lats: std::collections::HashSet<u64> = d
                .supported_shapes()
                .into_iter()
                .map(|s| ptx_latency(d, s))
                .collect();
            assert_eq!(lats.len(), 1, "{d:?}");
        }
    }

    #[test]
    fn effective_tile_reshapes_for_tall_wide() {
        assert_eq!(effective_tile(WmmaDtype::F16F32, (8, 32, 16)), (8, 16, 16));
        assert_eq!(effective_tile(WmmaDtype::F16F32, (32, 8, 16)), (16, 8, 16));
        assert_eq!(effective_tile(WmmaDtype::F16F32, (16, 16, 16)), (16, 8, 16));
    }

    #[test]
    #[should_panic(expected = "unsupported WMMA shape")]
    fn rejects_bad_shape() {
        sass_instruction_count(WmmaDtype::F64F64, (17, 8, 4));
    }

    #[test]
    fn movm_only_for_half_inputs() {
        assert!(WmmaDtype::F16F16.uses_movm());
        assert!(WmmaDtype::Bf16F32.uses_movm());
        assert!(!WmmaDtype::Tf32F32.uses_movm());
        assert!(!WmmaDtype::U8S32.uses_movm());
    }

    #[test]
    fn dtype_from_fragment_types() {
        use PtxType::*;
        assert_eq!(
            WmmaDtype::from_fragment_types(&[F16, F16, F16, F16]),
            Some(WmmaDtype::F16F16)
        );
        assert_eq!(
            WmmaDtype::from_fragment_types(&[F32, F16, F16, F32]),
            Some(WmmaDtype::F16F32)
        );
        assert_eq!(
            WmmaDtype::from_fragment_types(&[S32, U8, U8, S32]),
            Some(WmmaDtype::U8S32)
        );
    }
}
