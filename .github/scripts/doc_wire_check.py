#!/usr/bin/env python3
"""Doc-drift check for the serving surface (sibling of doc_links.py).

Two invariants, both extracted from the source of truth so the check
cannot itself drift:

1. every wire ``Mode`` the server parses (the ``Some("…") => Mode::…``
   arms of ``parse_request`` in ``rust/src/oracle/batch.rs``) must be
   documented in ``docs/WIRE.md``;
2. every CLI subcommand dispatched by ``rust/src/main.rs`` (the
   top-level ``"…" =>`` match arms) must be documented in
   ``docs/USAGE.md``.

A new mode or subcommand without docs — or a doc rename that orphans
one — fails CI with the missing names listed.

Usage: doc_wire_check.py  (run from the repo root)
"""

import re
import sys

BATCH_RS = "rust/src/oracle/batch.rs"
MAIN_RS = "rust/src/main.rs"
WIRE_MD = "docs/WIRE.md"
USAGE_MD = "docs/USAGE.md"

# `Some("predict") => Mode::Predict,` arms in parse_request.
MODE_ARM_RE = re.compile(r'Some\("([a-z0-9_-]+)"\)\s*=>\s*Mode::')
# Top-level subcommand arms of `match args.cmd.as_str()` — exactly one
# match-arm indent level deep inside main(), e.g. `        "serve" =>`.
CMD_ARM_RE = re.compile(r'^        "([a-z0-9-]+)" =>', re.MULTILINE)


def read(path):
    with open(path, encoding="utf-8") as f:
        return f.read()


def main():
    failures = []

    modes = sorted(set(MODE_ARM_RE.findall(read(BATCH_RS))))
    if len(modes) < 10:
        failures.append(
            f"{BATCH_RS}: found only {len(modes)} wire modes {modes} — "
            "the parse_request extraction regex is probably stale"
        )
    wire_md = read(WIRE_MD)
    for mode in modes:
        # A mode counts as documented when it appears as a backticked
        # token (`predict`) anywhere in WIRE.md.
        if f"`{mode}`" not in wire_md and f"`{mode} " not in wire_md:
            failures.append(f"{WIRE_MD}: wire mode `{mode}` is undocumented")

    cmds = sorted(set(CMD_ARM_RE.findall(read(MAIN_RS))))
    if len(cmds) < 15:
        failures.append(
            f"{MAIN_RS}: found only {len(cmds)} subcommands {cmds} — "
            "the match-arm extraction regex is probably stale"
        )
    usage_md = read(USAGE_MD)
    for cmd in cmds:
        # USAGE.md is plain help text, not markdown — a subcommand
        # counts as documented when its name starts a word anywhere.
        if not re.search(rf"(?m)(?:^|\s){re.escape(cmd)}\b", usage_md):
            failures.append(f"{USAGE_MD}: subcommand '{cmd}' is undocumented")

    for f in failures:
        print(f)
    if failures:
        sys.exit(f"{len(failures)} serving doc-drift failure(s)")
    print(
        f"doc drift clean: {len(modes)} wire modes in {WIRE_MD}, "
        f"{len(cmds)} subcommands in {USAGE_MD}"
    )


if __name__ == "__main__":
    main()
