#!/usr/bin/env python3
"""Docs link check: every relative markdown link and every `rust/src/...`
(or `docs/...`, `tests/...`, `benches/...`, `.github/...`) path mentioned
in the given markdown files must exist in the checkout.

Usage: doc_links.py <file.md> [more.md ...]

External links (http/https/mailto) and intra-page anchors are ignored.
Exits non-zero listing every dangling reference.
"""

import os
import re
import sys

# [text](target) markdown links, minus images' leading "!".
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
# Back-ticked repo paths: `rust/src/sim/throughput.rs`, `docs/WIRE.md`, ...
PATH_RE = re.compile(
    r"`((?:rust/src|docs|tests|benches|examples|vendor|\.github)/[A-Za-z0-9_./-]+)`"
)


def check_file(md_path):
    bad = []
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()

    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            bad.append(f"{md_path}: link target {target!r} -> {resolved} missing")

    for path in PATH_RE.findall(text):
        # Trailing `/` marks a directory reference; `...` elisions and
        # glob-ish mentions are skipped.
        if "*" in path or "..." in path:
            continue
        if not os.path.exists(path.rstrip("/")):
            bad.append(f"{md_path}: path reference `{path}` missing from the tree")

    return bad


def main():
    if len(sys.argv) < 2:
        sys.exit(f"usage: {sys.argv[0]} <file.md> [more.md ...]")
    failures = []
    for md in sys.argv[1:]:
        failures.extend(check_file(md))
    for f in failures:
        print(f)
    if failures:
        sys.exit(f"{len(failures)} dangling doc reference(s)")
    print(f"all references resolve across {len(sys.argv) - 1} file(s)")


if __name__ == "__main__":
    main()
