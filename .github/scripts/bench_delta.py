#!/usr/bin/env python3
"""Bench trajectory check: compare this run's BENCH_*.json against the
previous successful main run's artifact.

Usage: bench_delta.py <baseline_dir> <new_dir>

Prints a median-delta table per bench file and exits non-zero when any
series regressed by more than REGRESSION_PCT.  Series that appear on
only one side (renamed/new benches) are reported but never fail the
check, and a missing file on either side skips that file — the check
must not brick CI when benches are added or reshaped.
"""

import json
import os
import sys

REGRESSION_PCT = 25.0
FILES = (
    "BENCH_campaign.json",
    "BENCH_oracle.json",
    "BENCH_throughput.json",
    "BENCH_serve.json",
    "BENCH_gemm.json",
    "BENCH_mlp.json",
)


def load_series(path):
    with open(path) as f:
        doc = json.load(f)
    return {row["name"]: row for row in doc.get("results", [])}


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} <baseline_dir> <new_dir>")
    base_dir, new_dir = sys.argv[1], sys.argv[2]
    regressions = []

    for name in FILES:
        base_path = os.path.join(base_dir, name)
        new_path = os.path.join(new_dir, name)
        if not os.path.exists(base_path) or not os.path.exists(new_path):
            print(f"{name}: missing on one side; skipping")
            continue
        base = load_series(base_path)
        new = load_series(new_path)

        print(f"\n== {name} — median delta vs previous main ==")
        print(f"{'series':<40} {'prev (ms)':>12} {'now (ms)':>12} {'delta':>9}")
        for series, row in new.items():
            prev = base.get(series)
            if prev is None:
                print(f"{series:<40} {'(new series)':>12}")
                continue
            p, n = prev["median_ns"], row["median_ns"]
            delta = (n - p) / p * 100.0 if p else 0.0
            flag = ""
            if delta > REGRESSION_PCT:
                flag = "  REGRESSION"
                regressions.append(f"{name}:{series} +{delta:.1f}%")
            print(f"{series:<40} {p / 1e6:>12.2f} {n / 1e6:>12.2f} {delta:>8.1f}%{flag}")
        for series in sorted(set(base) - set(new)):
            print(f"{series:<40} {'(dropped)':>12}")

    if regressions:
        sys.exit(
            "median regression >"
            + f"{REGRESSION_PCT:.0f}% vs previous main: "
            + ", ".join(regressions)
        )
    print(f"\nno series regressed by more than {REGRESSION_PCT:.0f}%")


if __name__ == "__main__":
    main()
