"""AOT export: lower every L2 model variant to HLO *text* artifacts.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md.

Run via `make artifacts` (no-op when inputs are unchanged):
    cd python && python -m compile.aot --out ../artifacts/model.hlo.txt

Writes `model.hlo.txt` (the primary artifact: the f16_f16 Fig. 5 chain)
plus one `wmma_*.hlo.txt` per Table III variant, and a `manifest.json`
describing shapes/dtypes for the rust loader.
"""

import argparse
import json
import os

import jax

from .model import variant_specs

PRIMARY = "wmma_chain_f16_f16"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True,
                    help="path of the primary artifact (model.hlo.txt); "
                         "siblings are written next to it")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, fn, example_args in variant_specs():
        text = lower_variant(fn, example_args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in example_args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
        if name == PRIMARY:
            with open(args.out, "w") as f:
                f.write(text)
            print(f"wrote {args.out} (primary = {name})")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(manifest)} variants)")


if __name__ == "__main__":
    main()
