"""L2 — the WMMA microbenchmark compute graph (paper Fig. 5) in JAX.

Mirrors the structure of the paper's CUDA tensor-core microbenchmark:

  Part 1/2: fragments are declared and loaded        -> cast_in inside kernel
  Part 3:   4 independent fragment chains, each runs -> `wmma_microbench`
            iters dependent mma_sync ops
  Part 4:   store accumulators                       -> function outputs

Each variant is lowered ONCE by aot.py to HLO text; the Rust coordinator
(rust/src/runtime) loads + executes the compiled artifact on its request
path, so the simulator's tensor-core numerics are validated against real
XLA execution of the Pallas kernel — python never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels.ref import WMMA_CONFIGS
from .kernels.wmma import pallas_mma, pallas_mma_chain

jax.config.update("jax_enable_x64", True)

# Fig. 5 uses 4 fragments ("we run 4 TC instructions, 1 per TC") and loops.
NUM_FRAGMENTS = 4
DEFAULT_ITERS = 4


def wmma_single(a, b, c, *, config):
    """One WMMA op: D = A*B + C through the Pallas tile kernel."""
    return (pallas_mma(a, b, c, config),)


def wmma_microbench(a4, b4, c4, *, config, iters=DEFAULT_ITERS):
    """The Fig. 5 kernel: 4 independent fragment chains (one per TC in an
    SM), each a dependent chain of `iters` mma ops.

    a4: (4, M, K), b4: (4, K, N), c4: (4, M, N) — stacked fragments.
    Returns the 4 accumulators, stacked.
    """
    outs = [
        pallas_mma_chain(a4[i], b4[i], c4[i], config, iters)
        for i in range(NUM_FRAGMENTS)
    ]
    return (jnp.stack(outs),)


def _io_dtype(cfg):
    return jnp.dtype(cfg["io_dtype"])


def variant_specs():
    """(name, fn, example_args) for every artifact aot.py must produce.

    Names match what rust/src/runtime/artifacts.rs expects:
      wmma_<config>          — single mma, primary PTX shape
      wmma_chain_<config>    — the full Fig. 5 microbenchmark graph
    """
    import functools

    specs = []
    for name, cfg in WMMA_CONFIGS.items():
        m, n, k = cfg["shape"]
        dt = _io_dtype(cfg)
        single = functools.partial(wmma_single, config=name)
        specs.append((
            f"wmma_{name}",
            single,
            (jax.ShapeDtypeStruct((m, k), dt),
             jax.ShapeDtypeStruct((k, n), dt),
             jax.ShapeDtypeStruct((m, n), dt)),
        ))
        chain = functools.partial(wmma_microbench, config=name, iters=DEFAULT_ITERS)
        specs.append((
            f"wmma_chain_{name}",
            chain,
            (jax.ShapeDtypeStruct((NUM_FRAGMENTS, m, k), dt),
             jax.ShapeDtypeStruct((NUM_FRAGMENTS, k, n), dt),
             jax.ShapeDtypeStruct((NUM_FRAGMENTS, m, n), dt)),
        ))
    return specs
