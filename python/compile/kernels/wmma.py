"""L1 — Pallas WMMA tile-MMA kernels.

The paper's tensor-core hot-spot, D = A*B + C, expressed as a Pallas
kernel whose grid iterates exactly the way the Ampere hardware decomposes
the WMMA PTX instruction into SASS instructions (Table III):

  PTX wmma.mma.sync m16n16k16.f16  ->  2x HMMA.16816  (N split 16 -> 2x8)
  PTX wmma.mma.sync m16n16k8.tf32  ->  4x HMMA.1684   (N split x2, K split x2)
  PTX wmma.mma.sync m8n8k4.f64     ->  1x DMMA.884
  PTX wmma.mma.sync m8n8k32.u4     ->  1x IMMA.8832

Each grid step of the kernel is one SASS-instruction-equivalent tile, so
the same decomposition arithmetic drives the Rust tensor-core timing model
(rust/src/tensor/) and this kernel — the Pallas grid *is* the paper's
SASS-instruction count.

Hardware adaptation (DESIGN.md #Hardware-Adaptation): warp fragment
registers become VMEM blocks via BlockSpec; MOVM transposes become index
maps; the MXU analogue accumulates in fp32 via preferred_element_type.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated on the interpret path and TPU
performance is estimated statically (EXPERIMENTS.md #Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import WMMA_CONFIGS, acc_compute_dtype, cast_in

jax.config.update("jax_enable_x64", True)


def _mma_kernel(a_ref, b_ref, c_ref, o_ref, *, nsteps_k, acc_dtype, compute_dtype):
    """One SASS-tile MMA step: o = a @ b (+ c on the first k-step).

    Grid layout is (M/tm, N/tn, K/tk); the k axis is innermost so the
    accumulator block stays resident (the fragment registers of the WMMA
    API; VMEM in the TPU mapping).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...].astype(compute_dtype)

    a = a_ref[...]
    b = b_ref[...]
    partial = jnp.matmul(a, b, preferred_element_type=compute_dtype)
    o_ref[...] += partial

    @pl.when(k == nsteps_k - 1)
    def _done():
        # Round the full-precision accumulator to the fragment dtype once,
        # at the end — matching the TC's internal-accumulate-then-round.
        o_ref[...] = o_ref[...].astype(acc_dtype).astype(compute_dtype)


def sass_grid(shape, sass_tile):
    """SASS decomposition of a PTX WMMA shape: grid dims and instruction
    count.  This arithmetic is mirrored verbatim in rust/src/tensor/."""
    (m, n, k), (tm, tn, tk) = shape, sass_tile
    assert m % tm == 0 and n % tn == 0 and k % tk == 0, (shape, sass_tile)
    return (m // tm, n // tn, k // tk)


def effective_tile(config, shape=None):
    """SASS tile adapted to the PTX shape.

    One SASS MMA instruction always retires the same number of MACs for a
    dtype (e.g. 16*8*16 = 2048 for HMMA.16816) but the hardware re-shapes
    the tile for the wide/tall PTX shapes: m8n32k16 decomposes as two
    8x16x16 tiles, m32n8k16 as two 16x8x16 tiles.  This is why the paper
    finds latency *shape-independent within a dtype* on Ampere — the SASS
    instruction count never changes.
    """
    cfg = WMMA_CONFIGS[config]
    m, n, k = shape or cfg["shape"]
    tm, tn, tk = cfg["sass_tile"]
    macs = tm * tn * tk
    tm = min(m, tm)
    assert macs % (tm * tk) == 0, (config, shape)
    tn = min(n, macs // (tm * tk))
    return (tm, tn, tk)


def sass_instruction_count(config, shape=None):
    """Number of SASS MMA instructions one PTX WMMA instruction becomes —
    Table III's '2*HMMA...' / '4*HMMA...' / '1*DMMA' counts."""
    cfg = WMMA_CONFIGS[config]
    mnk = shape or cfg["shape"]
    gm, gn, gk = sass_grid(mnk, effective_tile(config, mnk))
    return gm * gn * gk


def pallas_mma(a, b, c, config, shape=None, interpret=True):
    """D = A*B + C as a Pallas kernel with one grid step per SASS tile.

    a: (M, K), b: (K, N), c: (M, N) in the config's *io* dtype; returns D
    in the io dtype (precision conversion happens inside, mirroring
    wmma::load_matrix_sync / store_matrix_sync).
    """
    cfg = WMMA_CONFIGS[config]
    mnk = shape or cfg["shape"]
    m, n, k = mnk
    tm, tn, tk = effective_tile(config, mnk)
    grid = sass_grid(mnk, (tm, tn, tk))
    compute_dtype = acc_compute_dtype(cfg)

    a = cast_in(a, cfg["in_dtype"])
    b = cast_in(b, cfg["in_dtype"])
    c = jnp.asarray(c).astype(cfg["acc_dtype"])

    kern = functools.partial(
        _mma_kernel,
        nsteps_k=grid[2],
        acc_dtype=jnp.dtype(cfg["acc_dtype"]) if cfg["acc_dtype"] != "int32" else jnp.int32,
        compute_dtype=compute_dtype,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),  # A fragment
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),  # B fragment
            pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),   # C fragment
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), compute_dtype),
        interpret=interpret,
    )(a, b, c)
    return out.astype(cfg["acc_dtype"]).astype(cfg["io_dtype"])


def pallas_mma_chain(a, b, c, config, iters, shape=None, interpret=True):
    """Fig. 5's Part-3 loop: iterate c <- A*B + c `iters` times through the
    Pallas kernel (same A and B each step, like the microbenchmark)."""
    d = c
    for _ in range(iters):
        d = pallas_mma(a, b, d, config, shape=shape, interpret=interpret)
    return d


# ---------------------------------------------------------------------------
# Static TPU performance estimate (interpret mode has no TPU wall-clock).
# ---------------------------------------------------------------------------

def vmem_bytes(config, shape=None):
    """VMEM footprint of one kernel invocation's resident blocks:
    A tile + B tile + accumulator tile, in fragment precision."""
    cfg = WMMA_CONFIGS[config]
    m, n, k = shape or cfg["shape"]
    tm, tn, tk = cfg["sass_tile"]
    in_bits = {"float16": 16, "bfloat16": 16, "tf32": 32, "float64": 64,
               "uint8": 8, "uint4": 4}[cfg["in_dtype"]]
    acc_bits = {"float16": 16, "float32": 32, "float64": 64, "int32": 32}[cfg["acc_dtype"]]
    return (tm * tk * in_bits + tk * tn * in_bits) // 8 + (tm * tn * acc_bits) // 8


def mxu_utilization(config, shape=None):
    """Useful-MAC fraction of the issued SASS tiles: MACs the PTX shape
    needs / (SASS instruction count x MACs one SASS tile retires).  The
    structural stand-in for the paper's measured/theoretical GB/s ratio —
    1.0 for every supported shape (no padding waste), <1.0 if a shape had
    to be padded up to tile boundaries."""
    cfg = WMMA_CONFIGS[config]
    m, n, k = shape or cfg["shape"]
    tm, tn, tk = cfg["sass_tile"]
    tile_macs = tm * tn * tk

    def ceil_div(a, b):
        return -(-a // b)

    etm, etn, etk = (min(m, tm), None, tk)
    # padded instruction count uses the same reshaping rule as effective_tile
    etn = tile_macs // (etm * etk)
    issued = ceil_div(m, etm) * ceil_div(n, etn) * ceil_div(k, etk)
    return (m * n * k) / (issued * tile_macs)
