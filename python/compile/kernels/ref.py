"""Pure-jnp correctness oracle for the WMMA tile-MMA kernels.

Every Pallas kernel in `wmma.py` is checked against these references by
pytest (`python/tests/`).  The references implement the *semantics* of the
Ampere WMMA instruction D = A*B + C for each (input dtype, accumulator
dtype) pair of the paper's Table III, including the precision behaviour:

  fp16 x fp16 -> fp16 | fp32      (HMMA.16816.F16 / .F32)
  bf16 x bf16 -> fp32             (HMMA.16816.F32.BF16)
  tf32 x tf32 -> fp32             (HMMA.1684.F32.TF32; 10-bit mantissa)
  fp64 x fp64 -> fp64             (DMMA.884)
  u8   x u8   -> s32              (IMMA.16816.U8.U8)
  u4   x u4   -> s32              (IMMA.8832.U4.U4; values in [0, 15])
"""

import jax.numpy as jnp

# Table III: every WMMA dtype config the Ampere ISA supports, keyed by the
# name used throughout the build (aot.py artifact names, rust runtime ids).
#   in_dtype:  dtype the A/B fragments are held in on-chip
#   acc_dtype: accumulator (C/D fragment) dtype
#   io_dtype:  dtype at the HLO interface (rust feeds plain f32/f64/i32
#              buffers; precision conversion happens *inside* the graph,
#              mirroring the fragment-load step of the WMMA API)
#   shape:     the paper's primary (M, N, K) PTX shape for the config
#   sass_tile: the SASS-instruction tile the hardware iterates with, i.e.
#              the Pallas BlockSpec tile (see DESIGN.md #Hardware-Adaptation)
WMMA_CONFIGS = {
    "f16_f16": dict(in_dtype="float16", acc_dtype="float16", io_dtype="float32",
                    shape=(16, 16, 16), sass_tile=(16, 8, 16), sass_name="HMMA.16816.F16"),
    "f16_f32": dict(in_dtype="float16", acc_dtype="float32", io_dtype="float32",
                    shape=(16, 16, 16), sass_tile=(16, 8, 16), sass_name="HMMA.16816.F32"),
    "bf16_f32": dict(in_dtype="bfloat16", acc_dtype="float32", io_dtype="float32",
                     shape=(16, 16, 16), sass_tile=(16, 8, 16), sass_name="HMMA.16816.F32.BF16"),
    "tf32_f32": dict(in_dtype="tf32", acc_dtype="float32", io_dtype="float32",
                     shape=(16, 16, 8), sass_tile=(16, 8, 4), sass_name="HMMA.1684.F32.TF32"),
    "f64_f64": dict(in_dtype="float64", acc_dtype="float64", io_dtype="float64",
                    shape=(8, 8, 4), sass_tile=(8, 8, 4), sass_name="DMMA.884"),
    "u8_s32": dict(in_dtype="uint8", acc_dtype="int32", io_dtype="int32",
                   shape=(16, 16, 16), sass_tile=(16, 8, 16), sass_name="IMMA.16816.U8.U8"),
    "u4_s32": dict(in_dtype="uint4", acc_dtype="int32", io_dtype="int32",
                   shape=(8, 8, 32), sass_tile=(8, 8, 32), sass_name="IMMA.8832.U4.U4"),
}

# All PTX-level shapes each config supports (Table III col 1).  The paper
# found latency is shape-independent within a dtype on Ampere; the tests
# sweep these to assert the kernels are correct for every one.
WMMA_PTX_SHAPES = {
    "f16_f16": [(16, 16, 16), (8, 32, 16), (32, 8, 16)],
    "f16_f32": [(16, 16, 16), (8, 32, 16), (32, 8, 16)],
    "bf16_f32": [(16, 16, 16), (8, 32, 16), (32, 8, 16)],
    "tf32_f32": [(16, 16, 8)],
    "f64_f64": [(8, 8, 4)],
    "u8_s32": [(16, 16, 16), (32, 8, 16), (8, 32, 16)],
    "u4_s32": [(8, 8, 32)],
}


def round_to_tf32(x):
    """TensorFloat-32: f32 with the mantissa truncated to 10 bits.

    The tensor core reads f32 operands but only feeds the top 10 mantissa
    bits to the datapath.  Truncation (zeroing the low 13 bits) matches the
    zeroed low bits observable through the WMMA API.
    """
    x = jnp.asarray(x, jnp.float32)
    bits = jnp.bitwise_and(x.view(jnp.uint32), jnp.uint32(0xFFFFE000))
    return bits.view(jnp.float32)


def quantize_u4(x):
    """Clamp integer inputs into the u4 domain [0, 15] (sub-byte fragments
    are carried unpacked, one nibble per int32 lane, at the HLO interface)."""
    return jnp.clip(jnp.asarray(x, jnp.int32), 0, 15)


def cast_in(x, in_dtype):
    """Fragment-load precision conversion: io buffer -> fragment dtype."""
    if in_dtype == "tf32":
        return round_to_tf32(x)
    if in_dtype == "uint4":
        return quantize_u4(x)
    return jnp.asarray(x).astype(in_dtype)


def acc_compute_dtype(cfg):
    """Dtype products are accumulated in on the simulated datapath."""
    if cfg["in_dtype"] in ("uint4", "uint8"):
        return jnp.int32
    if cfg["in_dtype"] == "float64":
        return jnp.float64
    return jnp.float32  # fp16/bf16/tf32 all accumulate in fp32 internally


def ref_mma(a, b, c, config):
    """Reference D = A*B + C with the precision semantics of `config`.

    a: (M, K), b: (K, N), c: (M, N) in the config's io dtype.
    The multiply runs in the input precision; products are accumulated in
    fp32 (resp. i32/f64) internally — Ampere TCs accumulate fp16 inputs in
    full precision, then round to the accumulator dtype.
    """
    cfg = WMMA_CONFIGS[config] if isinstance(config, str) else config
    in_dtype, acc_dtype = cfg["in_dtype"], cfg["acc_dtype"]
    compute = acc_compute_dtype(cfg)
    a = cast_in(a, in_dtype)
    b = cast_in(b, in_dtype)
    d = jnp.matmul(a, b, preferred_element_type=compute)
    # The C fragment is held in the accumulator dtype; the add runs in the
    # internal (full) precision and D is rounded once at the end.
    c = jnp.asarray(c).astype(acc_dtype).astype(compute)
    return (d + c).astype(acc_dtype)


def ref_mma_chain(a, b, c, config, iters):
    """Reference for the Fig. 5 microbenchmark loop:
    c_{i+1} = A*B + c_i  repeated `iters` times (same A, B each step)."""
    d = jnp.asarray(c)
    for _ in range(iters):
        d = ref_mma(a, b, d, config)
    return d


def ref_io(d, config):
    """Convert a fragment-dtype result back to the io dtype used at the
    HLO boundary (what the rust runtime sees)."""
    cfg = WMMA_CONFIGS[config] if isinstance(config, str) else config
    return jnp.asarray(d).astype(cfg["io_dtype"])
