"""L2 correctness: model graph shapes + semantics, AOT lowering sanity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from compile.aot import lower_variant, PRIMARY

jax.config.update("jax_enable_x64", True)


def test_variant_specs_cover_all_configs():
    names = {name for name, _, _ in model.variant_specs()}
    for config in ref.WMMA_CONFIGS:
        assert f"wmma_{config}" in names
        assert f"wmma_chain_{config}" in names
    assert PRIMARY in names


def test_wmma_single_matches_ref():
    config = "f16_f32"
    m, n, k = ref.WMMA_CONFIGS[config]["shape"]
    rng = np.random.default_rng(0)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    c = rng.standard_normal((m, n)).astype(np.float32)
    (got,) = model.wmma_single(a, b, c, config=config)
    want = ref.ref_io(ref.ref_mma(a, b, c, config), config)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("config", ["f16_f16", "u8_s32", "f64_f64"])
def test_wmma_microbench_is_4_independent_chains(config):
    cfg = ref.WMMA_CONFIGS[config]
    m, n, k = cfg["shape"]
    rng = np.random.default_rng(1)
    if cfg["io_dtype"] == "int32":
        a4 = rng.integers(0, 8, (4, m, k), dtype=np.int32)
        b4 = rng.integers(0, 8, (4, k, n), dtype=np.int32)
        c4 = rng.integers(0, 8, (4, m, n), dtype=np.int32)
    else:
        dt = np.dtype(cfg["io_dtype"])
        a4 = (rng.standard_normal((4, m, k)) * 0.25).astype(dt)
        b4 = (rng.standard_normal((4, k, n)) * 0.25).astype(dt)
        c4 = (rng.standard_normal((4, m, n)) * 0.25).astype(dt)
    (got,) = model.wmma_microbench(a4, b4, c4, config=config, iters=2)
    assert got.shape == (4, m, n)
    for i in range(4):
        want = ref.ref_io(ref.ref_mma_chain(a4[i], b4[i], c4[i], config, 2), config)
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want), rtol=2e-3, atol=2e-3
        )


def test_lowering_produces_hlo_text():
    """The AOT path must produce parseable HLO text (ENTRY + computation)
    for the primary variant — the artifact the rust runtime loads."""
    for name, fn, example_args in model.variant_specs():
        if name != PRIMARY:
            continue
        text = lower_variant(fn, example_args)
        assert "ENTRY" in text and "HloModule" in text
        assert "f16" in text  # fragments really are half precision in-graph
        return
    pytest.fail("primary variant missing")


def test_lowering_all_variants_smoke():
    """Every Table III variant lowers without error and mentions its
    fragment dtype in the HLO (the in-graph precision conversion exists)."""
    marker = {
        "f16_f16": "f16", "f16_f32": "f16", "bf16_f32": "bf16",
        "tf32_f32": "f32", "f64_f64": "f64", "u8_s32": "u8", "u4_s32": "s32",
    }
    for name, fn, example_args in model.variant_specs():
        if not name.startswith("wmma_") or name.startswith("wmma_chain"):
            continue
        config = name[len("wmma_"):]
        text = lower_variant(fn, example_args)
        assert marker[config] in text, name
