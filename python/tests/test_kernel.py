"""L1 correctness: Pallas WMMA kernel vs the pure-jnp oracle.

The CORE correctness signal of the python layer: for every Table III dtype
config and every supported PTX shape, the Pallas kernel (whose grid is the
SASS decomposition) must match ref.py bit-for-bit in the accumulator dtype.
Hypothesis sweeps values, shapes, and dtypes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.wmma import (
    pallas_mma,
    pallas_mma_chain,
    sass_grid,
    sass_instruction_count,
    vmem_bytes,
    mxu_utilization,
)

jax.config.update("jax_enable_x64", True)

CONFIGS = list(ref.WMMA_CONFIGS)


def make_inputs(config, shape, seed=0):
    cfg = ref.WMMA_CONFIGS[config]
    m, n, k = shape
    rng = np.random.default_rng(seed)
    if cfg["io_dtype"] == "int32":
        hi = 16 if cfg["in_dtype"] == "uint4" else 128
        a = rng.integers(0, hi, (m, k), dtype=np.int32)
        b = rng.integers(0, hi, (k, n), dtype=np.int32)
        c = rng.integers(-1000, 1000, (m, n), dtype=np.int32)
    else:
        dt = np.dtype(cfg["io_dtype"])
        a = rng.standard_normal((m, k)).astype(dt)
        b = rng.standard_normal((k, n)).astype(dt)
        c = rng.standard_normal((m, n)).astype(dt)
    return a, b, c


def assert_matches(config, got, want):
    """Int configs must match exactly; float configs whose SASS grid splits
    K (tf32: 2 k-tiles) accumulate partials in a different f32 order than
    one flat matmul — allow 1 ulp-scale slack there, exact otherwise."""
    cfg = ref.WMMA_CONFIGS[config]
    got, want = np.asarray(got), np.asarray(want)
    if cfg["io_dtype"] == "int32":
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("config", CONFIGS)
def test_mma_matches_ref_primary_shape(config):
    cfg = ref.WMMA_CONFIGS[config]
    a, b, c = make_inputs(config, cfg["shape"])
    got = pallas_mma(a, b, c, config)
    want = ref.ref_io(ref.ref_mma(a, b, c, config), config)
    assert_matches(config, got, want)


@pytest.mark.parametrize("config", CONFIGS)
def test_mma_all_ptx_shapes(config):
    """Table III column 1: every supported PTX shape for the dtype."""
    for shape in ref.WMMA_PTX_SHAPES[config]:
        a, b, c = make_inputs(config, shape, seed=hash(shape) % 2**31)
        got = pallas_mma(a, b, c, config, shape=shape)
        want = ref.ref_io(ref.ref_mma(a, b, c, config), config)
        assert_matches(config, got, want)


@pytest.mark.parametrize("config", CONFIGS)
@pytest.mark.parametrize("iters", [1, 2, 4])
def test_mma_chain_matches_ref(config, iters):
    """Fig. 5's dependent-mma loop."""
    cfg = ref.WMMA_CONFIGS[config]
    a, b, c = make_inputs(config, cfg["shape"], seed=iters)
    got = pallas_mma_chain(a, b, c, config, iters)
    want = ref.ref_io(ref.ref_mma_chain(a, b, c, config, iters), config)
    # fp16 chains accumulate rounding; compare in the accumulator dtype.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_sass_decomposition_counts():
    """Table III column 'Instructions': 2/2/2/4/1/2/1 SASS per PTX."""
    expected = {
        "f16_f16": 2, "f16_f32": 2, "bf16_f32": 2,
        "tf32_f32": 4, "f64_f64": 1, "u8_s32": 2, "u4_s32": 1,
    }
    for config, n in expected.items():
        assert sass_instruction_count(config) == n, config


def test_sass_decomposition_shape_invariant_within_dtype():
    """Paper: different PTX shapes of the same dtype produce the same
    number of SASS tiles (hence shape-independent latency on Ampere)."""
    for config, shapes in ref.WMMA_PTX_SHAPES.items():
        counts = {sass_instruction_count(config, s) for s in shapes}
        assert len(counts) == 1, (config, counts)


@given(
    st.sampled_from(CONFIGS),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_hypothesis_value_sweep(config, seed):
    """Random values in every dtype config must match the oracle exactly."""
    cfg = ref.WMMA_CONFIGS[config]
    a, b, c = make_inputs(config, cfg["shape"], seed=seed)
    got = pallas_mma(a, b, c, config)
    want = ref.ref_io(ref.ref_mma(a, b, c, config), config)
    assert_matches(config, got, want)


@given(
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_hypothesis_multi_tile_shapes(mi, ni, ki, seed):
    """Shapes that are any multiple of the SASS tile still match the
    oracle — the grid decomposition generalises past Table III's shapes."""
    config = "f16_f32"
    tm, tn, tk = ref.WMMA_CONFIGS[config]["sass_tile"]
    shape = (mi * tm, ni * tn, ki * tk)
    a, b, c = make_inputs(config, shape, seed=seed)
    got = pallas_mma(a, b, c, config, shape=shape)
    want = ref.ref_io(ref.ref_mma(a, b, c, config), config)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_tf32_truncation_semantics():
    """tf32 zeroes the low 13 mantissa bits — values differing only there
    multiply identically."""
    x = np.float32(1.0) + np.float32(2**-20)  # below tf32 precision
    a = np.full((16, 8), x, np.float32)
    a2 = np.ones((16, 8), np.float32)
    b = np.ones((8, 16), np.float32)
    c = np.zeros((16, 16), np.float32)
    d1 = pallas_mma(a, b, c, "tf32_f32")
    d2 = pallas_mma(a2, b, c, "tf32_f32")
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_u4_clamping():
    """u4 fragments clamp to [0, 15]."""
    a = np.full((8, 32), 99, np.int32)   # clamps to 15
    b = np.ones((32, 8), np.int32)
    c = np.zeros((8, 8), np.int32)
    d = pallas_mma(a, b, c, "u4_s32")
    np.testing.assert_array_equal(np.asarray(d), np.full((8, 8), 15 * 32, np.int32))


def test_grid_rejects_misaligned_shape():
    with pytest.raises(AssertionError):
        sass_grid((17, 16, 16), (16, 8, 16))


def test_vmem_budget():
    """#Perf L1 target: every SASS tile's resident blocks fit far under the
    128 KiB VMEM budget (DESIGN.md #9)."""
    for config in CONFIGS:
        assert vmem_bytes(config) <= 128 * 1024, config


def test_mxu_utilization_full_for_supported_shapes():
    """Paper's measured/theoretical ~= 1 for the supported shapes: our
    structural analogue — no padding waste, utilization == 1."""
    for config in CONFIGS:
        assert mxu_utilization(config) == pytest.approx(1.0), config
