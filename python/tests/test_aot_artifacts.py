"""Artifact integrity: the AOT outputs the rust runtime consumes."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts`")
def test_manifest_and_files_consistent():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest) == 14  # 7 dtypes x {single, chain}
    for name, meta in manifest.items():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert len(meta["args"]) == 3, name


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts`")
def test_artifacts_are_pure_hlo_text():
    # The interchange gotcha: text, never serialized protos (which the
    # xla crate's 0.5.1 extension rejects).
    for fname in os.listdir(ART):
        if fname.endswith(".hlo.txt"):
            head = open(os.path.join(ART, fname), "rb").read(64)
            assert head.startswith(b"HloModule"), fname
