//! Integration: the multi-warp throughput engine end to end — the
//! 1-warp byte-identity anchor against the latency path over every
//! Table V registry row, IPC monotonicity, determinism across engines
//! and pool reuse, per-arch port-width effects, and the oracle's
//! `"throughput"` serving mode agreeing with live simulation.

use ampere_ubench::arch;
use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::microbench::throughput::{run_sweep_with, ThroughputRow, DEFAULT_WARP_COUNTS};
use ampere_ubench::microbench::{alu, registry};
use ampere_ubench::oracle::{LatencyModel, LatencyOracle, Server};
use ampere_ubench::util::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, OnceLock};

/// One sweep shared by the read-only tests in this binary.
fn sweep() -> &'static Vec<ThroughputRow> {
    static SWEEP: OnceLock<Vec<ThroughputRow>> = OnceLock::new();
    SWEEP.get_or_init(|| {
        run_sweep_with(&Engine::new(AmpereConfig::small()), &DEFAULT_WARP_COUNTS)
            .expect("throughput sweep")
    })
}

/// Acceptance anchor: the 1-warp throughput replay reports the same CPI
/// as the existing latency simulation for all 132 Table V rows — the
/// property that lets every golden/conformance/fuzz gate keep passing.
#[test]
fn one_warp_cpi_is_byte_identical_to_the_latency_path_for_all_rows() {
    let engine = Engine::new(AmpereConfig::small());
    let latency = alu::run_table5_with(&engine).expect("latency Table V");
    let rows = sweep();
    let t5 = registry::table5();
    assert_eq!(rows.len(), t5.len() + engine.cfg().wmma_dtypes.len());
    let mut checked = 0;
    for ((t, reg), lat) in rows.iter().zip(&t5).zip(&latency) {
        assert_eq!(t.name, reg.name, "sweep order matches the registry");
        assert_eq!(
            t.cpi_1w, lat.measured.cpi,
            "{}: throughput 1-warp CPI {} vs latency CPI {}",
            t.name, t.cpi_1w, lat.measured.cpi
        );
        assert_eq!(t.n, 3, "{}: three protocol instances", t.name);
        checked += 1;
    }
    assert_eq!(checked, t5.len(), "all registry rows pinned");
}

#[test]
fn ipc_is_monotone_nondecreasing_in_warp_count_for_every_row() {
    for row in sweep() {
        assert_eq!(row.points.len(), DEFAULT_WARP_COUNTS.len(), "{}", row.name);
        for pair in row.points.windows(2) {
            assert!(
                pair[1].ipc_milli >= pair[0].ipc_milli,
                "{}: IPC fell from {} ({} warps) to {} ({} warps)",
                row.name,
                pair[0].ipc_milli,
                pair[0].warps,
                pair[1].ipc_milli,
                pair[1].warps
            );
        }
        let max = row.points.iter().map(|p| p.ipc_milli).max().unwrap();
        assert_eq!(row.peak_ipc_milli, max, "{}", row.name);
        assert!(
            DEFAULT_WARP_COUNTS.contains(&row.warps_to_peak),
            "{}: warps_to_peak {} outside the sweep",
            row.name,
            row.warps_to_peak
        );
        // Saturation point really is within 1% of the peak.
        let at = row
            .points
            .iter()
            .find(|p| p.warps == row.warps_to_peak)
            .unwrap();
        assert!(at.ipc_milli * 100 >= row.peak_ipc_milli * 99, "{}", row.name);
    }
}

#[test]
fn sweep_is_deterministic_across_engines_and_pool_reuse() {
    let engine = Engine::new(AmpereConfig::small());
    let first = run_sweep_with(&engine, &DEFAULT_WARP_COUNTS).unwrap();
    // Second sweep on the same engine: kernels cache-served, simulators
    // and warp schedulers recycled — results must not move.
    let second = run_sweep_with(&engine, &DEFAULT_WARP_COUNTS).unwrap();
    assert_eq!(first, second, "pooled rerun must be identical");
    assert!(
        engine.warp_pool_stats().reused > 0,
        "second sweep must reuse pooled schedulers: {:?}",
        engine.warp_pool_stats()
    );
    // And a completely fresh engine agrees too.
    assert_eq!(first, *sweep(), "independent engines must agree");
}

#[test]
fn port_widths_and_occupancies_shape_saturation_per_arch() {
    // add.u32: one INT port, occupancy 2 → peak 0.5 IPC, not reachable
    // by a single warp.
    let add = sweep().iter().find(|r| r.name == "add.u32").unwrap();
    assert!((400..=500).contains(&add.peak_ipc_milli), "{add:?}");
    assert!(add.warps_to_peak > 1, "one warp cannot saturate INT");

    // Doubling the INT ports in a custom spec raises the ceiling — the
    // ArchSpec field drives the scheduler.
    let mut wide = AmpereConfig::small();
    wide.arch_name = "wide-int".into();
    wide.int_pipe.ports = 2;
    wide.issue_width = 2;
    let engine = Engine::new(wide);
    let rows = registry::table5();
    let row = rows.iter().find(|r| r.name == "add.u32").unwrap();
    let wide_row = ampere_ubench::microbench::throughput::measure_row_with(
        &engine,
        row,
        &DEFAULT_WARP_COUNTS,
    )
    .unwrap();
    assert!(
        wide_row.peak_ipc_milli > add.peak_ipc_milli + 200,
        "2 ports must lift the peak: {} vs {}",
        wide_row.peak_ipc_milli,
        add.peak_ipc_milli
    );

    // Turing's occupancy-16 fp64 port (the once-dead config field) caps
    // add.f64 throughput well below Ampere's occupancy-4 pipe.
    let turing = Engine::new(arch::get("turing").unwrap().config.into_small());
    let f64_row = rows.iter().find(|r| r.name == "add.f64").unwrap();
    let t = ampere_ubench::microbench::throughput::measure_row_with(
        &turing,
        f64_row,
        &DEFAULT_WARP_COUNTS,
    )
    .unwrap();
    let a = sweep().iter().find(|r| r.name == "add.f64").unwrap();
    assert!(
        t.peak_ipc_milli < a.peak_ipc_milli,
        "turing fp64 peak {} must trail ampere {}",
        t.peak_ipc_milli,
        a.peak_ipc_milli
    );
}

/// Acceptance: the model's extracted `"throughput"` entries — and the
/// serving layer's answers — agree with live multi-warp simulation.
#[test]
fn oracle_throughput_mode_agrees_with_live_simulation() {
    let engine = Engine::new(AmpereConfig::small());
    let model = LatencyModel::extract(&engine).expect("extraction");
    let live = sweep();
    assert_eq!(
        model.throughput.len(),
        live.len(),
        "one model entry per swept row"
    );
    for row in live {
        let e = model
            .throughput_entry(&row.name)
            .unwrap_or_else(|err| panic!("{}: {err}", row.name));
        assert_eq!(e.cpi_1w, row.cpi_1w, "{}", row.name);
        assert_eq!(e.peak_ipc_milli, row.peak_ipc_milli, "{}", row.name);
        assert_eq!(e.warps_to_peak, row.warps_to_peak, "{}", row.name);
        let points: Vec<(u32, u64)> =
            row.points.iter().map(|p| (p.warps, p.ipc_milli)).collect();
        assert_eq!(e.points, points, "{}", row.name);
    }

    // The model round-trips through JSON with the curves intact.
    let back = LatencyModel::from_json_str(&model.to_json_string()).unwrap();
    assert_eq!(back, model);

    // And over the wire: one request per class of interest.
    let oracle = LatencyOracle::with_engine(model, Engine::new(AmpereConfig::small()));
    let server = Server::bind(Arc::new(oracle), "127.0.0.1:0").expect("bind");
    let handle = server.spawn().expect("spawn");
    let stream = TcpStream::connect(handle.addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    for name in ["add.u32", "add.f64", "f16_f16"] {
        let expect = live.iter().find(|r| r.name == name).unwrap();
        writeln!(
            stream,
            r#"{{"mode":"throughput","instr":"{name}","id":1}}"#
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{name}: {v:?}");
        assert_eq!(
            v.get("peak_ipc_milli").and_then(Value::as_u64),
            Some(expect.peak_ipc_milli),
            "{name}"
        );
        assert_eq!(
            v.get("warps_to_peak").and_then(Value::as_u64),
            Some(expect.warps_to_peak as u64),
            "{name}"
        );
        assert_eq!(
            v.get("cpi_1w").and_then(Value::as_u64),
            Some(expect.cpi_1w),
            "{name}"
        );
    }
    // Unknown names answer with an error, not a number.
    writeln!(stream, r#"{{"mode":"throughput","instr":"warp.drive"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
    handle.stop();
}
