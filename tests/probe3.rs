#[test]
#[ignore]
fn probe3() {
    use std::time::Instant;
    let cfg = ampere_ubench::config::AmpereConfig::a100();
    for d in ampere_ubench::tensor::ALL_DTYPES {
        let t = Instant::now();
        let src = ampere_ubench::microbench::wmma::fig5_kernel(d, 8);
        let t_gen = t.elapsed();
        let t = Instant::now();
        let prog = ampere_ubench::ptx::parse_program(&src).unwrap();
        let t_parse = t.elapsed();
        let t = Instant::now();
        let tp = ampere_ubench::translate::translate_program(&prog).unwrap();
        let t_tr = t.elapsed();
        let t = Instant::now();
        let mut sim = ampere_ubench::sim::Simulator::new(cfg.clone());
        sim.trace = ampere_ubench::sass::TraceRecorder::disabled();
        let t_new = t.elapsed();
        let t = Instant::now();
        for ch in 0..4u64 {
            let base = 0x20_0000u64 + ch * 0x1_0000;
            for i in 0..1024u64 {
                sim.mem.dram.write(base + 4 * i, &(1.0f32).to_bits().to_le_bytes());
            }
        }
        let t_seed = t.elapsed();
        let t = Instant::now();
        sim.run(&prog, &tp, &[0]).unwrap();
        let t_run = t.elapsed();
        println!("{:<10} gen {:?} parse {:?} tr {:?} new {:?} seed {:?} run {:?}",
            d.key(), t_gen, t_parse, t_tr, t_new, t_seed, t_run);
    }
}
