//! Property-based tests over the coordinator's invariants, driven by the
//! in-tree xorshift PRNG (`util::prng::check` replays failures by seed).
//!
//! Invariants covered:
//! * translation is deterministic and register-safe;
//! * measured CPI of a dependent chain never beats the independent form;
//! * clock reads are monotone; the measurement protocol is
//!   seed-independent;
//! * the cache model obeys LRU capacity bounds for any stride/size;
//! * generated Table V kernels always parse, translate, and run;
//! * f16/json substrates round-trip arbitrary values.

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::microbench::registry::{self, RegClass};
use ampere_ubench::microbench::{alu, run_measurement, INSTANCES};
use ampere_ubench::ptx::parse_program;
use ampere_ubench::sim::Simulator;
use ampere_ubench::translate::translate_program;
use ampere_ubench::util::f16;
use ampere_ubench::util::json;
use ampere_ubench::util::prng::{check, Rng};

#[test]
fn prop_every_registry_row_parses_translates_runs() {
    let cfg = AmpereConfig::a100();
    check("registry-rows", 40, |rng| {
        let rows = registry::table5();
        let row = &rows[rng.below(rows.len() as u64) as usize];
        let dependent = rng.bool() && alu::can_chain(row);
        let src = alu::kernel_for(row, dependent);
        let prog = parse_program(&src).map_err(|e| format!("{}: {e}", row.name))?;
        let tp = translate_program(&prog).map_err(|e| format!("{}: {e}", row.name))?;
        prog.validate()?;
        let mut sim = Simulator::new(cfg.clone());
        let r = sim
            .run(&prog, &tp, &[0x100000])
            .map_err(|e| format!("{}: {e}", row.name))?;
        if r.clock_reads.len() < 2 {
            return Err(format!("{}: lost clock reads", row.name));
        }
        Ok(())
    });
}

#[test]
fn prop_translation_is_deterministic() {
    check("translate-deterministic", 30, |rng| {
        let rows = registry::table5();
        let row = &rows[rng.below(rows.len() as u64) as usize];
        let src = alu::kernel_for(row, false);
        let prog = parse_program(&src).map_err(|e| e.to_string())?;
        let a = translate_program(&prog).map_err(|e| e.to_string())?;
        let b = translate_program(&prog).map_err(|e| e.to_string())?;
        for (x, y) in a.groups.iter().zip(&b.groups) {
            if x.mapping() != y.mapping() {
                return Err(format!("{}: {} vs {}", row.name, x.mapping(), y.mapping()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dependence_never_speeds_up() {
    let cfg = AmpereConfig::a100();
    check("dep>=indep", 25, |rng| {
        let rows = registry::table5();
        let chainable: Vec<_> = rows.iter().filter(|r| alu::can_chain(r)).collect();
        let row = chainable[rng.below(chainable.len() as u64) as usize];
        let indep =
            run_measurement(&cfg, &alu::kernel_for(row, false), INSTANCES, row.name, false)?;
        let dep = run_measurement(&cfg, &alu::kernel_for(row, true), INSTANCES, row.name, true)?;
        if dep.cpi < indep.cpi {
            return Err(format!("{}: dep {} < indep {}", row.name, dep.cpi, indep.cpi));
        }
        Ok(())
    });
}

#[test]
fn prop_clock_reads_are_monotone() {
    let cfg = AmpereConfig::a100();
    check("clock-monotone", 20, |rng| {
        // Random straight-line arithmetic between many clock reads.
        let ops = ["add.u32", "mul.lo.u32", "and.b32", "min.u32", "popc.b32"];
        let mut body = String::new();
        let reads = 3 + rng.below(4);
        for i in 0..reads {
            body.push_str(&format!("mov.u64 %rd{}, %clock64;\n ", 30 + i));
            let op = rng.pick(&ops);
            let n = 1 + rng.below(3);
            for j in 0..n {
                body.push_str(&format!("{op} %r{}, %r{}, %r7;\n ", 20 + j, 5 + j));
            }
        }
        let src = format!(
            ".visible .entry k(.param .u64 out) {{ {} {} ret; }}",
            ampere_ubench::microbench::REG_DECLS,
            body
        );
        let prog = parse_program(&src).map_err(|e| e.to_string())?;
        let tp = translate_program(&prog).map_err(|e| e.to_string())?;
        let mut sim = Simulator::new(cfg.clone());
        let r = sim.run(&prog, &tp, &[0]).map_err(|e| e.to_string())?;
        for w in r.clock_reads.windows(2) {
            if w[1] <= w[0] {
                return Err(format!("clock went backwards: {:?}", r.clock_reads));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cache_capacity_bound() {
    use ampere_ubench::memory::Cache;
    check("cache-lru", 40, |rng| {
        let line = 64usize << rng.below(2); // 64 or 128
        let assoc = 1 + rng.below(8) as usize;
        let sets = 1 + rng.below(64) as usize;
        let bytes = line * assoc * sets;
        let mut c = Cache::new(bytes, line, assoc);
        // working set strictly within capacity, any line-aligned stride
        // pattern: second pass must be all hits.
        let lines = (bytes / line) as u64;
        let used = 1 + rng.below(lines);
        let addrs: Vec<u64> = (0..used).map(|i| i * line as u64).collect();
        for a in &addrs {
            c.access(*a);
        }
        for a in &addrs {
            if !c.access(*a) {
                return Err(format!(
                    "miss on warm addr {a} (bytes={bytes}, line={line}, assoc={assoc})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pointer_chase_latency_bounded() {
    // Any chain the generator can produce must measure within the
    // [L1 hit, DRAM] bracket.
    let mut cfg = AmpereConfig::a100();
    cfg.memory.l2_bytes = 256 * 1024;
    cfg.memory.l1_bytes = 16 * 1024;
    check("chase-bounds", 8, |rng| {
        let ops = ["cv", "cg", "ca"];
        let op = rng.pick(&ops);
        let span = 8 * 1024u64 << rng.below(6);
        let mut body = String::new();
        for i in 0..8 {
            body.push_str(&format!(
                "ld.global.{op}.u64 %rd{}, [%rd{}];\n ",
                21 + i,
                20 + i
            ));
        }
        let src = format!(
            ".visible .entry k(.param .u64 arr) {{ {} ld.param.u64 %rd20, [arr];\n \
             mov.u64 %rd60, %clock64;\n {} mov.u64 %rd61, %clock64;\n ret; }}",
            ampere_ubench::microbench::REG_DECLS,
            body
        );
        let prog = parse_program(&src).map_err(|e| e.to_string())?;
        let tp = translate_program(&prog).map_err(|e| e.to_string())?;
        let mut sim = Simulator::new(cfg.clone());
        ampere_ubench::microbench::memory::seed_chain(&mut sim, 0x100000, span, 9);
        let r = sim.run(&prog, &tp, &[0x100000]).map_err(|e| e.to_string())?;
        let delta = r.clock_reads[1] - r.clock_reads[0];
        let per = (delta - 2) / 8;
        let lo = cfg.memory.l1_hit_latency;
        let hi = cfg.memory.dram_latency + 20;
        if !(lo..=hi).contains(&per) {
            return Err(format!("{op} span {span}: {per} outside [{lo}, {hi}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_f16_roundtrip_through_f32() {
    check("f16-roundtrip", 200, |rng| {
        // f32 values that fit in half must round-trip bit-exactly.
        let h = (rng.next_u32() & 0xFFFF) as u16;
        let f = f16::f16_bits_to_f32(h);
        if f.is_finite() {
            let back = f16::f32_to_f16_bits(f);
            if back != h {
                return Err(format!("{h:#06x} -> {f} -> {back:#06x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut Rng, depth: u32) -> json::Value {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.bool()),
            2 => json::Value::Num((rng.range(-1_000_000, 1_000_000) as f64) / 4.0),
            3 => json::Value::Str(format!("s{}-\"{}\"\n", rng.below(100), rng.below(10))),
            4 => json::Value::Arr(
                (0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect(),
            ),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_value(rng, depth + 1));
                }
                json::Value::Obj(m)
            }
        }
    }
    check("json-roundtrip", 100, |rng| {
        let v = random_value(rng, 0);
        let compact = json::parse(&json::to_string(&v)).map_err(|e| e.to_string())?;
        let pretty = json::parse(&json::to_string_pretty(&v)).map_err(|e| e.to_string())?;
        if compact != v || pretty != v {
            return Err(format!("roundtrip mismatch for {v:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_registry_dst_classes_are_consistent() {
    // Every generated kernel's destination register class must be
    // declared by REG_DECLS — guards registry typos.
    check("registry-classes", 114, |rng| {
        let rows = registry::table5();
        let row = &rows[rng.below(rows.len() as u64) as usize];
        let ok = matches!(
            row.dst,
            RegClass::H | RegClass::R | RegClass::F | RegClass::Rd | RegClass::Fd | RegClass::P
        );
        if !ok {
            return Err(format!("{}: bad dst class", row.name));
        }
        Ok(())
    });
}
