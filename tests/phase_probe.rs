//! Phase probes: where does a measurement actually spend its wall-clock
//! time?  Consolidates the former ad-hoc `probe3.rs` (per-phase WMMA
//! timing) and `perf_probe.rs` (Table V phase breakdown + raw simulated
//! instruction throughput) into one documented binary.
//!
//! These are diagnostics, not assertions — they print timings for a
//! human reading `--nocapture` output and are `#[ignore]`d so tier-1
//! stays fast.  Run them with:
//!
//! ```text
//! cargo test --release --test phase_probe -- --nocapture --ignored
//! ```

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::microbench::{alu, registry};
use ampere_ubench::ptx::parse_program;
use ampere_ubench::sim::Simulator;
use ampere_ubench::translate::translate_program;
use std::time::Instant;

/// Per-phase cost of one Fig.-5 WMMA measurement, per dtype: kernel
/// generation, parse, translate, simulator construction, DRAM seeding,
/// and the run itself.
#[test]
#[ignore]
fn wmma_phase_breakdown() {
    let cfg = AmpereConfig::a100();
    for d in ampere_ubench::tensor::ALL_DTYPES {
        let t = Instant::now();
        let src = ampere_ubench::microbench::wmma::fig5_kernel(d, 8);
        let t_gen = t.elapsed();
        let t = Instant::now();
        let prog = parse_program(&src).unwrap();
        let t_parse = t.elapsed();
        let t = Instant::now();
        let tp = translate_program(&prog).unwrap();
        let t_tr = t.elapsed();
        let t = Instant::now();
        let mut sim = Simulator::new(cfg.clone());
        sim.trace = ampere_ubench::sass::TraceRecorder::disabled();
        let t_new = t.elapsed();
        let t = Instant::now();
        for ch in 0..4u64 {
            let base = 0x20_0000u64 + ch * 0x1_0000;
            for i in 0..1024u64 {
                sim.mem.dram.write(base + 4 * i, &(1.0f32).to_bits().to_le_bytes());
            }
        }
        let t_seed = t.elapsed();
        let t = Instant::now();
        sim.run(&prog, &tp, &[0]).unwrap();
        let t_run = t.elapsed();
        println!(
            "{:<10} gen {:?} parse {:?} tr {:?} new {:?} seed {:?} run {:?}",
            d.key(),
            t_gen,
            t_parse,
            t_tr,
            t_new,
            t_seed,
            t_run
        );
    }
}

/// Average per-kernel cost of each Table V phase across the whole
/// registry, plus raw simulated-SASS throughput on a long loop.
#[test]
#[ignore]
fn table5_phase_breakdown() {
    let cfg = AmpereConfig::a100();
    let rows = registry::table5();
    let srcs: Vec<String> = rows.iter().map(|r| alu::kernel_for(r, false)).collect();
    let n = srcs.len() as f64;

    let t = Instant::now();
    let progs: Vec<_> = srcs.iter().map(|s| parse_program(s).unwrap()).collect();
    println!("parse:     {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    let t = Instant::now();
    let tps: Vec<_> = progs.iter().map(|p| translate_program(p).unwrap()).collect();
    println!("translate: {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    let t = Instant::now();
    let mut sims: Vec<_> = (0..progs.len()).map(|_| Simulator::new(cfg.clone())).collect();
    println!("sim-new:   {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    let t = Instant::now();
    for ((p, tp), sim) in progs.iter().zip(&tps).zip(&mut sims) {
        sim.run(p, tp, &[0x100000]).unwrap();
    }
    println!("sim-run:   {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    // raw simulated-instruction throughput on a long loop
    let src = format!(
        ".visible .entry k() {{ {} mov.u64 %rd1, 0;\n$L:\n add.u64 %rd1, %rd1, 1;\n \
         add.u32 %r1, %r2, 1;\n add.u32 %r3, %r4, 1;\n add.u32 %r5, %r6, 1;\n \
         setp.lt.u64 %p1, %rd1, 1000000;\n @%p1 bra $L;\n ret; }}",
        ampere_ubench::microbench::REG_DECLS
    );
    let p = parse_program(&src).unwrap();
    let tp = translate_program(&p).unwrap();
    let mut sim = Simulator::new(cfg.clone());
    sim.trace = ampere_ubench::sass::TraceRecorder::disabled();
    let t = Instant::now();
    let r = sim.run(&p, &tp, &[]).unwrap();
    let secs = t.elapsed().as_secs_f64();
    println!(
        "loop:      {:.1} M SASS instr/s ({} instrs in {:.2}s)",
        r.sass_instructions as f64 / secs / 1e6,
        r.sass_instructions,
        secs
    );
}
