//! Integration: the serving transport under pipelined and abusive
//! clients — many in-flight frames answered strictly in order, byte
//! dribble, half-open connections, a stalled reader with responses
//! pending, streamed batch envelopes over both framings, a hot reload
//! landing between pipelined frames, and the client-sent partial-magic
//! desync.  On Linux these drive the epoll reactor; elsewhere the
//! thread-per-connection fallback must behave identically.

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::oracle::{wire, LatencyModel, LatencyOracle, Server, ServerHandle};
use ampere_ubench::util::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// One extracted model shared by every test in this binary (extraction
/// runs the full campaign once).
fn model() -> &'static LatencyModel {
    static MODEL: OnceLock<LatencyModel> = OnceLock::new();
    MODEL.get_or_init(|| {
        LatencyModel::extract(&Engine::new(AmpereConfig::small())).expect("extraction")
    })
}

fn oracle() -> LatencyOracle {
    LatencyOracle::with_engine(model().clone(), Engine::new(AmpereConfig::small()))
}

fn spawn_server() -> ServerHandle {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    server.spawn().expect("spawn")
}

struct Conn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Conn {
    fn open(handle: &ServerHandle) -> Conn {
        let stream = TcpStream::connect(handle.addr()).expect("connect");
        stream.set_nodelay(true).expect("nodelay");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Conn { stream, reader }
    }

    fn read_json_line(&mut self) -> Value {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("receive");
        assert!(n > 0, "server closed the connection mid-conversation");
        json::parse(line.trim()).expect("response is JSON")
    }
}

#[test]
fn pipelined_json_requests_answer_strictly_in_order() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    const N: u64 = 32;
    let mut burst = String::new();
    for i in 0..N {
        burst.push_str(&format!(
            "{{\"mode\":\"predict\",\"instr\":\"add.u32\",\"id\":{i}}}\n"
        ));
    }
    c.stream.write_all(burst.as_bytes()).expect("send burst");
    for i in 0..N {
        let v = c.read_json_line();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");
        assert_eq!(
            v.get("id").and_then(Value::as_u64),
            Some(i),
            "responses out of request order: {v:?}"
        );
    }
    // The connection stays interactive after the burst.
    c.stream.write_all(b"{\"mode\":\"ping\"}\n").expect("send");
    assert_eq!(c.read_json_line().get("pong"), Some(&Value::Bool(true)));
    handle.stop();
}

#[test]
fn pipelined_binary_frames_answer_strictly_in_order_across_modes() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    const N: u64 = 24;
    let mut burst = Vec::new();
    for i in 0..N {
        let request = match i % 3 {
            0 => Value::obj().set("mode", "ping").set("id", i),
            1 => Value::obj()
                .set("mode", "predict")
                .set("instr", "add.u32")
                .set("id", i),
            _ => Value::obj().set("mode", "stats").set("id", i),
        };
        burst.extend_from_slice(&wire::encode_frame(&request));
    }
    c.stream.write_all(&burst).expect("send burst");
    for i in 0..N {
        let v = match wire::read_frame(&mut c.reader).expect("read frame") {
            wire::FrameRead::Frame(p) => wire::decode_value(&p).expect("decode"),
            other => panic!("expected a response frame, got {other:?}"),
        };
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");
        assert_eq!(
            v.get("id").and_then(Value::as_u64),
            Some(i),
            "responses out of request order: {v:?}"
        );
    }
    handle.stop();
}

#[test]
fn one_byte_dribble_still_frames_requests() {
    let handle = spawn_server();

    // JSON line fed one byte at a time.
    let mut c = Conn::open(&handle);
    for &b in b"{\"mode\":\"ping\",\"id\":7}\n" {
        c.stream.write_all(&[b]).expect("dribble");
        c.stream.flush().expect("flush");
        std::thread::sleep(Duration::from_millis(1));
    }
    let v = c.read_json_line();
    assert_eq!(v.get("pong"), Some(&Value::Bool(true)), "{v:?}");
    assert_eq!(v.get("id").and_then(Value::as_u64), Some(7));

    // A binary frame fed one byte at a time — the magic byte, then the
    // length header, then the payload all arrive in separate segments.
    let mut c = Conn::open(&handle);
    let frame = wire::encode_frame(&Value::obj().set("mode", "ping").set("id", 8_u64));
    for &b in &frame {
        c.stream.write_all(&[b]).expect("dribble");
        c.stream.flush().expect("flush");
        std::thread::sleep(Duration::from_millis(1));
    }
    match wire::read_frame(&mut c.reader).expect("read frame") {
        wire::FrameRead::Frame(p) => {
            let v = wire::decode_value(&p).expect("decode");
            assert_eq!(v.get("pong"), Some(&Value::Bool(true)), "{v:?}");
            assert_eq!(v.get("id").and_then(Value::as_u64), Some(8));
        }
        other => panic!("expected a response frame, got {other:?}"),
    }
    handle.stop();
}

#[test]
fn half_open_client_receives_every_pipelined_response_then_eof() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    const N: u64 = 16;
    let mut burst = String::new();
    for i in 0..N {
        burst.push_str(&format!(
            "{{\"mode\":\"predict\",\"instr\":\"add.u32\",\"id\":{i}}}\n"
        ));
    }
    c.stream.write_all(burst.as_bytes()).expect("send burst");
    // Half-close: we will never send again, but every in-flight
    // request must still answer before the server hangs up.
    c.stream.shutdown(Shutdown::Write).expect("shutdown write");
    for i in 0..N {
        let v = c.read_json_line();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");
        assert_eq!(v.get("id").and_then(Value::as_u64), Some(i));
    }
    let mut line = String::new();
    assert_eq!(
        c.reader.read_line(&mut line).expect("eof read"),
        0,
        "server must close once a half-open connection is fully answered: {line:?}"
    );
    handle.stop();
}

#[test]
fn stalled_reader_with_pipelined_responses_drains_without_loss() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    // Each roundtrip is a large ping batch, so the un-read responses
    // pile hundreds of kilobytes into the server's per-connection
    // write buffer while we stall.
    const BATCHES: u64 = 16;
    const SLOTS: u64 = 600;
    let batch = Value::Arr(
        (0..SLOTS).map(|i| Value::obj().set("mode", "ping").set("id", i)).collect(),
    );
    let mut line_bytes = json::to_string(&batch).into_bytes();
    line_bytes.push(b'\n');
    for _ in 0..BATCHES {
        c.stream.write_all(&line_bytes).expect("send batch");
    }
    // Stall: give the server time to answer everything into its write
    // buffer (and the socket) while nobody reads.
    std::thread::sleep(Duration::from_millis(500));
    for b in 0..BATCHES {
        let v = c.read_json_line();
        let arr = v.as_arr().unwrap_or_else(|| panic!("batch {b} not an array"));
        assert_eq!(arr.len() as u64, SLOTS, "batch {b} lost slots");
        for (i, r) in arr.iter().enumerate() {
            assert_eq!(r.get("ok"), Some(&Value::Bool(true)), "batch {b} slot {i}");
            assert_eq!(r.get("id").and_then(Value::as_u64), Some(i as u64));
        }
    }
    // Nothing was dropped and the connection is still live.
    c.stream.write_all(b"{\"mode\":\"ping\"}\n").expect("send");
    assert_eq!(c.read_json_line().get("pong"), Some(&Value::Bool(true)));
    handle.stop();
}

#[test]
fn streaming_envelope_flushes_partials_then_terminal_json() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    c.stream
        .write_all(
            concat!(
                r#"{"stream":[{"mode":"ping","id":0},"#,
                r#"{"mode":"predict","instr":"add.u32","id":1},"#,
                r#"{"mode":"ping","id":2}],"id":"env"}"#,
                "\n"
            )
            .as_bytes(),
        )
        .expect("send envelope");

    let mut seen = [false; 3];
    for _ in 0..3 {
        let v = c.read_json_line();
        assert_eq!(v.get("partial"), Some(&Value::Bool(true)), "{v:?}");
        let index = v.get("index").and_then(Value::as_u64).expect("index") as usize;
        assert!(!seen[index], "slot {index} streamed twice");
        seen[index] = true;
        let resp = v.get("response").expect("response");
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("id").and_then(Value::as_u64), Some(index as u64));
    }
    let terminal = c.read_json_line();
    assert_eq!(terminal.get("done"), Some(&Value::Bool(true)), "{terminal:?}");
    assert_eq!(terminal.get("ok"), Some(&Value::Bool(true)));
    assert_eq!(terminal.get("streamed").and_then(Value::as_u64), Some(3));
    assert_eq!(terminal.get("failed").and_then(Value::as_u64), Some(0));
    assert_eq!(terminal.get("id").and_then(Value::as_str), Some("env"));

    // A failing slot streams its error and the terminal counts it;
    // the envelope itself still succeeds.
    c.stream
        .write_all(b"{\"stream\":[{\"mode\":\"predict\"}],\"id\":5}\n")
        .expect("send envelope");
    let partial = c.read_json_line();
    assert_eq!(partial.get("partial"), Some(&Value::Bool(true)));
    let resp = partial.get("response").expect("response");
    assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
    let terminal = c.read_json_line();
    assert_eq!(terminal.get("failed").and_then(Value::as_u64), Some(1));
    assert_eq!(terminal.get("ok"), Some(&Value::Bool(true)));
    assert_eq!(terminal.get("id").and_then(Value::as_u64), Some(5));

    // Ordinary requests keep working after a stream.
    c.stream.write_all(b"{\"mode\":\"ping\"}\n").expect("send");
    assert_eq!(c.read_json_line().get("pong"), Some(&Value::Bool(true)));
    handle.stop();
}

#[test]
fn streaming_envelope_flushes_partial_frames_then_terminal_binary() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    let envelope = Value::obj()
        .set(
            "stream",
            Value::Arr(
                (0..4_u64)
                    .map(|i| Value::obj().set("mode", "ping").set("id", i))
                    .collect(),
            ),
        )
        .set("id", 9_u64);
    c.stream.write_all(&wire::encode_frame(&envelope)).expect("send envelope");

    let mut seen = [false; 4];
    loop {
        match wire::read_frame(&mut c.reader).expect("read frame") {
            wire::FrameRead::Partial(p) => {
                let v = wire::decode_value(&p).expect("decode partial");
                assert_eq!(v.get("partial"), Some(&Value::Bool(true)), "{v:?}");
                let index =
                    v.get("index").and_then(Value::as_u64).expect("index") as usize;
                assert!(!seen[index], "slot {index} streamed twice");
                seen[index] = true;
                let resp = v.get("response").expect("response");
                assert_eq!(resp.get("pong"), Some(&Value::Bool(true)), "{resp:?}");
            }
            wire::FrameRead::Frame(p) => {
                // The terminal is an ordinary frame — and by protocol it
                // arrives only after every partial.
                let v = wire::decode_value(&p).expect("decode terminal");
                assert_eq!(v.get("done"), Some(&Value::Bool(true)), "{v:?}");
                assert_eq!(v.get("streamed").and_then(Value::as_u64), Some(4));
                assert_eq!(v.get("failed").and_then(Value::as_u64), Some(0));
                assert_eq!(v.get("id").and_then(Value::as_u64), Some(9));
                break;
            }
            other => panic!("unexpected frame read: {other:?}"),
        }
    }
    assert!(seen.iter().all(|s| *s), "terminal before every partial: {seen:?}");

    // The stream tag is unambiguous: an ordinary frame still roundtrips.
    c.stream
        .write_all(&wire::encode_frame(&Value::obj().set("mode", "ping")))
        .expect("send");
    match wire::read_frame(&mut c.reader).expect("read frame") {
        wire::FrameRead::Frame(p) => {
            let v = wire::decode_value(&p).expect("decode");
            assert_eq!(v.get("pong"), Some(&Value::Bool(true)), "{v:?}");
        }
        other => panic!("expected a response frame, got {other:?}"),
    }
    handle.stop();
}

#[test]
fn client_sent_partial_magic_is_a_desync_and_closes() {
    let handle = spawn_server();
    let mut c = Conn::open(&handle);

    // A healthy roundtrip first, so the desync is mid-stream.
    c.stream
        .write_all(&wire::encode_frame(&Value::obj().set("mode", "ping")))
        .expect("send");
    match wire::read_frame(&mut c.reader).expect("read frame") {
        wire::FrameRead::Frame(_) => {}
        other => panic!("expected a response frame, got {other:?}"),
    }

    // 0xB2 is server→client only; inbound it desynchronizes the stream.
    c.stream.write_all(&[wire::PARTIAL_MAGIC]).expect("send partial magic");
    match wire::read_frame(&mut c.reader).expect("read error frame") {
        wire::FrameRead::Frame(p) => {
            let v = wire::decode_value(&p).expect("decode");
            let err = v.get("error").and_then(Value::as_str).expect("error");
            assert!(err.contains("bad frame magic 0xb2"), "{err}");
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    match wire::read_frame(&mut c.reader) {
        Ok(wire::FrameRead::Eof) | Err(_) => {}
        other => panic!("connection should close after desync: {other:?}"),
    }
    handle.stop();
}

/// A hot reload landing between pipelined frames: every in-flight
/// batch answers coherently from exactly one model snapshot, nothing
/// drops, and frames submitted after the reload acknowledgment answer
/// from the new model.
#[test]
fn hot_reload_lands_between_pipelined_frames() {
    const BATCH: u64 = 4;
    const DEPTH: u64 = 8;
    let base = model().lookup("add.u32").expect("add.u32 in model").cpi;
    let new_cpi = base + 7;

    let mut bumped = model().clone();
    {
        let e = bumped.instructions.get_mut("add.u32").expect("add.u32 entry");
        e.cpi += 7;
        if let Some(d) = e.dep_cpi.as_mut() {
            *d += 7;
        }
    }
    let bumped_path = std::env::temp_dir().join("serve_reactor_reload_bumped.json");
    let bumped_path = bumped_path.to_str().unwrap().to_string();
    bumped.save(&bumped_path).unwrap();

    let handle = spawn_server();
    let mut c = Conn::open(&handle);
    let batch = Value::Arr(
        (0..BATCH)
            .map(|i| {
                Value::obj().set("mode", "predict").set("instr", "add.u32").set("id", i)
            })
            .collect(),
    );
    let mut line_bytes = json::to_string(&batch).into_bytes();
    line_bytes.push(b'\n');

    // One pipelined window in flight while the reload fires from a
    // second connection.
    for _ in 0..DEPTH {
        c.stream.write_all(&line_bytes).expect("send window");
    }
    let mut r = Conn::open(&handle);
    r.stream
        .write_all(format!("{{\"mode\":\"reload\",\"model\":\"{bumped_path}\"}}\n").as_bytes())
        .expect("send reload");
    let ack = r.read_json_line();
    assert_eq!(ack.get("ok"), Some(&Value::Bool(true)), "{ack:?}");
    assert_eq!(ack.get("reloads").and_then(Value::as_u64), Some(1));

    // Drain the window: every batch is coherent and from one of the
    // two models (the swap point is a race by construction).
    let coherent_cpi = |v: &Value| -> u64 {
        let arr = v.as_arr().expect("batch response is an array");
        assert_eq!(arr.len() as u64, BATCH);
        let cpi = arr[0].get("cpi").and_then(Value::as_u64).expect("cpi");
        for r in arr {
            assert_eq!(r.get("ok"), Some(&Value::Bool(true)), "{r:?}");
            assert_eq!(
                r.get("cpi").and_then(Value::as_u64),
                Some(cpi),
                "torn read inside one pipelined batch: {v:?}"
            );
        }
        assert!(cpi == base || cpi == new_cpi, "cpi {cpi} matches neither model");
        cpi
    };
    for _ in 0..DEPTH {
        coherent_cpi(&c.read_json_line());
    }

    // The reload acknowledgment happened-before anything we send now,
    // so fresh frames on the same pipelined connection see the new
    // model (allow a brief settle for snapshot propagation).
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        c.stream.write_all(&line_bytes).expect("send post-reload");
        if coherent_cpi(&c.read_json_line()) == new_cpi {
            break;
        }
        assert!(Instant::now() < deadline, "reload never became visible");
    }

    handle.stop();
    let _ = std::fs::remove_file(&bumped_path);
}
