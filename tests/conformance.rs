//! Golden conformance: the paper's tables rendered via the
//! `report::*_json` builders and diffed against the pinned snapshots in
//! `tests/golden/` — the integration-level twin of the
//! `repro conformance` CLI path — plus the registry name/SASS pin that
//! makes accidental renames or mapping drift fail loudly.

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::fuzz::golden;
use ampere_ubench::microbench::registry;

#[test]
fn registry_names_and_sass_match_snapshot() {
    let path = format!("{}/registry_sass.txt", golden::default_dir());
    let snapshot = std::fs::read_to_string(&path).expect("checked-in registry snapshot");
    assert_eq!(
        snapshot,
        golden::registry_snapshot(),
        "registry drifted from tests/golden/registry_sass.txt — if the rename or \
         mapping change is intentional, regenerate with `repro conformance --update` \
         and review the diff"
    );
    assert_eq!(snapshot.lines().count(), registry::names().len());
}

#[test]
fn golden_files_exist_and_parse() {
    use ampere_ubench::util::json::parse;
    let dir = golden::default_dir();
    for t in golden::TABLES {
        let path = format!("{dir}/{t}.json");
        let src = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
        let v = parse(&src).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(v.get("table").and_then(|x| x.as_str()), Some(t), "{path}");
        assert!(v.get("expect").is_some(), "{path} has no expect value");
    }
}

#[test]
fn conformance_passes_against_checked_in_goldens() {
    // The acceptance gate: Tables I–V + Fig. 4 within the pinned
    // per-cell tolerances and Table V's calibration floors.
    let engine = Engine::new(AmpereConfig::small());
    let report = golden::check(&engine, &golden::default_dir());
    assert!(report.pass(), "{}", report.render());
    // registry + 6 tables were all actually checked
    assert_eq!(report.tables.len(), 1 + golden::TABLES.len());
}
