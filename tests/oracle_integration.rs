//! Integration: the Rust runtime (L3) executing the AOT-compiled
//! JAX/Pallas artifacts (L2/L1) through PJRT, validated against the
//! simulator's functional tensor-core model.
//!
//! Needs `make artifacts` — tests skip (with a notice) if the artifact
//! directory is absent so `cargo test` stays runnable standalone.

use ampere_ubench::runtime::{validate_wmma_against_sim, Artifacts, HostTensor, Oracle};
use ampere_ubench::tensor::{WmmaDtype, ALL_DTYPES};

fn oracle_or_skip() -> Option<Oracle> {
    match Artifacts::discover(Artifacts::default_dir()) {
        Ok(a) => Some(Oracle::new(a).expect("PJRT CPU client must come up")),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_covers_all_dtypes() {
    let Some(oracle) = oracle_or_skip() else { return };
    let variants = oracle.variants();
    for d in ALL_DTYPES {
        assert!(variants.contains(&format!("wmma_{}", d.key())), "{}", d.key());
        assert!(
            variants.contains(&format!("wmma_chain_{}", d.key())),
            "chain {}",
            d.key()
        );
    }
}

#[test]
fn sim_matches_oracle_for_every_dtype() {
    let Some(mut oracle) = oracle_or_skip() else { return };
    for d in ALL_DTYPES {
        let err = validate_wmma_against_sim(&mut oracle, d).unwrap();
        let tol = if d == WmmaDtype::F16F16 { 0.05 } else { 1e-3 };
        assert!(err <= tol, "{}: max err {err}", d.key());
    }
}

#[test]
fn oracle_applies_fragment_precision() {
    // tf32 truncates the mantissa to 10 bits: values differing only
    // below that must multiply identically — through the *compiled
    // artifact*, not just the python test suite.
    let Some(mut oracle) = oracle_or_skip() else { return };
    let (m, n, k) = WmmaDtype::Tf32F32.primary_shape();
    let (m, n, k) = (m as usize, n as usize, k as usize);
    let eps = 2f64.powi(-20);
    let a1 = vec![1.0 + eps; m * k];
    let a2 = vec![1.0; m * k];
    let b = vec![1.0; k * n];
    let c = vec![0.0; m * n];
    let d1 = oracle.wmma_single(WmmaDtype::Tf32F32, &a1, &b, &c).unwrap();
    let d2 = oracle.wmma_single(WmmaDtype::Tf32F32, &a2, &b, &c).unwrap();
    assert_eq!(d1, d2, "tf32 truncation must hide the 2^-20 perturbation");

    // ...while f64 keeps it.
    let (m, n, k) = WmmaDtype::F64F64.primary_shape();
    let (m, n, k) = (m as usize, n as usize, k as usize);
    let a1 = vec![1.0 + eps; m * k];
    let a2 = vec![1.0; m * k];
    let b = vec![1.0; k * n];
    let c = vec![0.0; m * n];
    let d1 = oracle.wmma_single(WmmaDtype::F64F64, &a1, &b, &c).unwrap();
    let d2 = oracle.wmma_single(WmmaDtype::F64F64, &a2, &b, &c).unwrap();
    assert_ne!(d1, d2, "f64 keeps the perturbation");
}

#[test]
fn chain_artifact_runs_fig5_semantics() {
    // wmma_chain_*: 4 fragments × 4 dependent mmas. Feeding A = 0 must
    // return C unchanged (D = 0·B + C at every step).
    let Some(mut oracle) = oracle_or_skip() else { return };
    let meta = oracle.meta("wmma_chain_f16_f32").unwrap().clone();
    let shapes: Vec<Vec<usize>> = meta.args.iter().map(|a| a.shape.clone()).collect();
    let numel = |s: &Vec<usize>| s.iter().product::<usize>();
    let a = HostTensor::F32(vec![0.0; numel(&shapes[0])], shapes[0].clone());
    let b = HostTensor::F32(vec![2.0; numel(&shapes[1])], shapes[1].clone());
    let c_vals: Vec<f32> = (0..numel(&shapes[2])).map(|i| (i % 5) as f32).collect();
    let c = HostTensor::F32(c_vals.clone(), shapes[2].clone());
    let out = oracle.execute("wmma_chain_f16_f32", &[a, b, c]).unwrap();
    let want: Vec<f64> = c_vals.iter().map(|x| *x as f64).collect();
    assert_eq!(out, want);
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(mut oracle) = oracle_or_skip() else { return };
    let t0 = std::time::Instant::now();
    oracle.executable("wmma_f16_f16").unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    oracle.executable("wmma_f16_f16").unwrap();
    let warm = t1.elapsed();
    assert!(warm < cold / 2, "cached lookup {warm:?} vs compile {cold:?}");
}

#[test]
fn unknown_variant_is_an_error() {
    let Some(mut oracle) = oracle_or_skip() else { return };
    assert!(oracle.executable("wmma_f8_f8").is_err());
}
