//! Integration: the memory-level-parallelism engine end to end — the
//! 1-warp byte-identity anchor after the scheduler grew per-level
//! bandwidth channels, saturation-curve monotonicity across all five
//! built-in presets, the 32× worst-case bank-conflict serialization,
//! model ↔ serve ↔ live agreement for the `"mlp"` wire mode, lenient
//! loading of pre-MLP model JSON, and the Table IV latency pin staying
//! invariant under the new bandwidth fields.

use ampere_ubench::arch;
use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::microbench::mlp::{
    bank_conflict_ways, run_mlp_sweep_with, MlpRow, DEFAULT_MLP_DEGREES,
};
use ampere_ubench::microbench::throughput::run_sweep_with;
use ampere_ubench::microbench::{alu, memory, registry};
use ampere_ubench::oracle::{LatencyModel, LatencyOracle, Server};
use ampere_ubench::sim::{mem_service_cycles, MemLevel, MemStep, ALL_MEM_LEVELS};
use ampere_ubench::util::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// Acceptance anchor: with the memory channels in the scheduler, the
/// 1-warp throughput replay still reports the same CPI as the latency
/// simulation for every Table V row — single-warp gaps already carry
/// the full latency, so the bandwidth model must charge nothing.
#[test]
fn one_warp_replay_stays_byte_identical_with_memory_channels() {
    let engine = Engine::new(AmpereConfig::small());
    let latency = alu::run_table5_with(&engine).expect("latency Table V");
    let rows = run_sweep_with(&engine, &[1]).expect("1-warp sweep");
    let t5 = registry::table5();
    let mut checked = 0;
    for ((t, reg), lat) in rows.iter().zip(&t5).zip(&latency) {
        assert_eq!(t.name, reg.name, "sweep order matches the registry");
        assert_eq!(
            t.cpi_1w, lat.measured.cpi,
            "{}: throughput 1-warp CPI {} vs latency CPI {}",
            t.name, t.cpi_1w, lat.measured.cpi
        );
        let p = &t.points[0];
        assert_eq!(p.warps, 1, "{}", t.name);
        checked += 1;
    }
    assert_eq!(checked, t5.len(), "all registry rows pinned");
}

fn assert_curves_well_formed(arch_name: &str, rows: &[MlpRow]) {
    assert_eq!(rows.len(), ALL_MEM_LEVELS.len(), "{arch_name}");
    for row in rows {
        let key = row.level.key();
        assert_eq!(row.points.len(), DEFAULT_MLP_DEGREES.len(), "{arch_name}/{key}");
        // MLP = 1 is exactly the measured anchor.
        assert_eq!(
            row.points[0].per_access_milli,
            row.latency * 1000,
            "{arch_name}/{key}: MLP=1 must equal the anchor latency"
        );
        // Monotone non-increasing per-access cost — more parallelism
        // never makes an access slower.
        for w in row.points.windows(2) {
            assert!(
                w[1].per_access_milli <= w[0].per_access_milli,
                "{arch_name}/{key}: curve rose: {:?}",
                row.points
            );
        }
        // Achieved bandwidth never exceeds the ceiling, and the knee is
        // a swept degree.
        assert!(
            row.points.last().unwrap().bw_milli() <= row.peak_bw_milli,
            "{arch_name}/{key}"
        );
        assert!(DEFAULT_MLP_DEGREES.contains(&row.knee_mlp), "{arch_name}/{key}");
        assert!(row.service >= 1, "{arch_name}/{key}");
    }
}

/// Every built-in preset produces well-formed, monotone saturation
/// curves for all four bandwidth-modelled levels.
#[test]
fn saturation_curves_are_monotone_for_all_five_presets() {
    for name in ["ampere", "volta", "turing", "hopper", "blackwell"] {
        let cfg = arch::get(name).expect("builtin preset").config.into_small();
        let engine = Engine::new(cfg);
        let rows = run_mlp_sweep_with(&engine).expect("mlp sweep");
        assert_curves_well_formed(name, &rows);
    }
    // The successor generations carry wider L2/DRAM paths, so their
    // ceilings must beat Ampere's.
    let bw = |name: &str, level: MemLevel| {
        let cfg = arch::get(name).unwrap().config;
        mem_service_cycles(&cfg.memory, MemStep { level, conflict_ways: 1 })
    };
    assert!(bw("hopper", MemLevel::Global) < bw("ampere", MemLevel::Global));
    assert!(bw("blackwell", MemLevel::L2) < bw("ampere", MemLevel::L2));
    assert!(bw("turing", MemLevel::L2) > bw("ampere", MemLevel::L2));
}

/// The paper's 32-bank layout: a stride-32 (column) access pattern
/// serializes a warp to exactly 32× the conflict-free service cost, and
/// the conflict degree follows `gcd(stride % 32, 32)`.
#[test]
fn worst_case_bank_conflict_serializes_exactly_32x() {
    let m = AmpereConfig::a100().memory;
    let clean = mem_service_cycles(&m, MemStep { level: MemLevel::Shared, conflict_ways: 1 });
    let worst = mem_service_cycles(&m, MemStep { level: MemLevel::Shared, conflict_ways: 32 });
    assert_eq!(worst, 32 * clean, "32-way conflict must serialize 32x");
    assert_eq!(bank_conflict_ways(32), 32, "column stride: full conflict");
    assert_eq!(bank_conflict_ways(33), 1, "padded column: conflict free");
    assert_eq!(bank_conflict_ways(0), 1, "broadcast: conflict free");
    for stride in 1..=64u64 {
        let ways = bank_conflict_ways(stride);
        assert!(
            matches!(ways, 1 | 2 | 4 | 8 | 16 | 32),
            "stride {stride}: illegal degree {ways}"
        );
        let cost = mem_service_cycles(&m, MemStep {
            level: MemLevel::Shared,
            conflict_ways: ways,
        });
        assert_eq!(cost, ways * clean, "stride {stride}");
    }
}

/// Acceptance: the extracted model's `mlp` section, the serving layer's
/// `"mlp"` wire mode, and live simulation agree exactly — and a model
/// written before the section existed still loads (leniently) and
/// explains what re-extraction would add.
#[test]
fn model_serve_and_live_agree_and_legacy_models_load_leniently() {
    let engine = Engine::new(AmpereConfig::small());
    let live = run_mlp_sweep_with(&engine).expect("live sweep");
    let model = LatencyModel::extract(&engine).expect("extraction");
    assert_eq!(model.mlp.len(), live.len(), "one model entry per level");
    for row in &live {
        let e = model
            .mlp_entry(row.level.key())
            .unwrap_or_else(|err| panic!("{}: {err}", row.level.key()));
        assert_eq!(e.latency, row.latency, "{}", row.level.key());
        assert_eq!(e.service, row.service, "{}", row.level.key());
        assert_eq!(e.peak_bw_milli, row.peak_bw_milli, "{}", row.level.key());
        assert_eq!(e.knee_mlp, row.knee_mlp, "{}", row.level.key());
        let points: Vec<(u32, u64)> =
            row.points.iter().map(|p| (p.mlp, p.per_access_milli)).collect();
        assert_eq!(e.points, points, "{}", row.level.key());
    }

    // Lenient legacy load: strip the whole section and the model still
    // parses; the lookup error tells the user how to get the curves.
    let mut doc = json::parse(&model.to_json_string()).unwrap();
    if let Value::Obj(map) = &mut doc {
        assert!(map.remove("mlp").is_some(), "serialized model carries mlp");
    }
    let legacy = LatencyModel::from_json_str(&json::to_string_pretty(&doc)).unwrap();
    assert!(legacy.mlp.is_empty());
    let err = legacy.mlp_entry("global").unwrap_err();
    assert!(err.contains("extract-model"), "unhelpful error: {err}");

    // Over the wire: one request per level, byte-agreeing with live.
    let oracle = LatencyOracle::with_engine(model, Engine::new(AmpereConfig::small()));
    let server = Server::bind(Arc::new(oracle), "127.0.0.1:0").expect("bind");
    let handle = server.spawn().expect("spawn");
    let stream = TcpStream::connect(handle.addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut stream = stream;
    for row in &live {
        let key = row.level.key();
        writeln!(stream, r#"{{"mode":"mlp","instr":"{key}","id":3}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{key}: {v:?}");
        assert_eq!(v.get("level").and_then(Value::as_str), Some(key));
        assert_eq!(v.get("latency").and_then(Value::as_u64), Some(row.latency), "{key}");
        assert_eq!(
            v.get("knee_mlp").and_then(Value::as_u64),
            Some(row.knee_mlp as u64),
            "{key}"
        );
        let points = v.get("points").and_then(Value::as_arr).unwrap();
        assert_eq!(points.len(), row.points.len(), "{key}");
        for (wire, live_p) in points.iter().zip(&row.points) {
            assert_eq!(
                wire.get("mlp").and_then(Value::as_u64),
                Some(live_p.mlp as u64),
                "{key}"
            );
            assert_eq!(
                wire.get("per_access_milli").and_then(Value::as_u64),
                Some(live_p.per_access_milli),
                "{key}"
            );
        }
    }
    // Unknown levels answer with an error naming the valid keys.
    writeln!(stream, r#"{{"mode":"mlp","instr":"texture"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
    assert!(
        v.get("error").and_then(Value::as_str).unwrap_or("").contains("global"),
        "{v:?}"
    );
    handle.stop();
}

/// Satellite pin: growing `MemoryConfig` bandwidth fields must not move
/// a single Table IV latency — the pointer chase is MLP = 1 by
/// construction, where bandwidth never binds.  Golden snapshots and the
/// benches stay byte-identical as a corollary.
#[test]
fn table4_latencies_are_invariant_under_bandwidth_fields() {
    let base_cfg = AmpereConfig::small();
    let baseline = memory::run_table4_with(&Engine::new(base_cfg.clone())).unwrap();

    let mut warped = base_cfg;
    warped.memory.sector_bytes = 64;
    warped.memory.l1_bytes_per_cycle = 1;
    warped.memory.l2_bytes_per_cycle = 1;
    warped.memory.dram_bytes_per_cycle = 1;
    warped.memory.shared_banks = 16;
    warped.memory.shared_bank_bytes = 8;
    let after = memory::run_table4_with(&Engine::new(warped)).unwrap();

    assert_eq!(baseline.len(), after.len());
    for (a, b) in baseline.iter().zip(&after) {
        assert_eq!(a.level, b.level);
        assert_eq!(a.cpi, b.cpi, "{}: latency moved with bandwidth fields", a.level.name());
        assert_eq!(a.loads, b.loads, "{}", a.level.name());
    }
}
