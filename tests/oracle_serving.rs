//! Integration: the latency-oracle subsystem end to end — model
//! extraction, JSON round-trip, static-vs-live self-consistency over
//! the full Table V registry, and the loopback TCP serving path with
//! concurrent clients over both framings (JSON lines and binary
//! frames), hot model reload under live traffic, and the pinned
//! JSON-mode byte protocol.

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::microbench::{alu, registry};
use ampere_ubench::oracle::{wire, LatencyModel, LatencyOracle, Server};
use ampere_ubench::util::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// One extracted model shared by every test in this binary (extraction
/// runs the full campaign once).
fn model() -> &'static LatencyModel {
    static MODEL: OnceLock<LatencyModel> = OnceLock::new();
    MODEL.get_or_init(|| {
        LatencyModel::extract(&Engine::new(AmpereConfig::small())).expect("extraction")
    })
}

fn oracle() -> LatencyOracle {
    LatencyOracle::with_engine(model().clone(), Engine::new(AmpereConfig::small()))
}

#[test]
fn extracted_model_round_trips_through_json() {
    let m = model();
    assert!(m.instructions.len() >= 95, "Table V-sized: {}", m.instructions.len());
    assert_eq!(m.memory.len(), 5, "five Table IV levels");
    assert_eq!(m.wmma.len(), 7, "seven Table III dtypes");
    assert_eq!(m.cold_start_cpi, vec![5, 3, 2, 2], "Table I curve");
    assert_eq!(m.clock_overhead, 2);

    let s = m.to_json_string();
    let back = LatencyModel::from_json_str(&s).expect("parse back");
    assert_eq!(&back, m, "serialize→parse is the identity");

    // And through a file, like `repro extract-model` writes it.
    let path = std::env::temp_dir().join("oracle_model_roundtrip.json");
    let path = path.to_str().unwrap();
    m.save(path).unwrap();
    assert_eq!(&LatencyModel::load(path).unwrap(), m);
    let _ = std::fs::remove_file(path);
}

#[test]
fn model_keys_are_unique_per_registry_row() {
    // Every Table V row must land its own entry — a key collision would
    // silently alias two instructions' CPIs.
    assert_eq!(
        model().instructions.len(),
        registry::table5().len(),
        "one model entry per registry row"
    );
}

/// Acceptance: for every Table V row, the static prediction from the
/// extracted model equals live `Engine` simulation of the same
/// microbenchmark kernel — same CPI, independent *and* dependent
/// variants.
#[test]
fn static_prediction_matches_live_sim_for_every_table5_row() {
    let o = oracle();
    let mut checked = 0;
    for row in registry::table5() {
        let src = alu::kernel_for(&row, false);
        let c = o.cross_check(&src).unwrap_or_else(|e| panic!("{}: {e}", row.name));
        assert!(
            c.matches,
            "{}: predicted {} vs simulated {}",
            row.name, c.predicted.cpi, c.simulated.cpi
        );
        assert_eq!(c.predicted.n, 3, "{}: three instances", row.name);
        checked += 1;

        if alu::can_chain(&row) {
            let dep_src = alu::kernel_for(&row, true);
            let c = o
                .cross_check(&dep_src)
                .unwrap_or_else(|e| panic!("{} (dep): {e}", row.name));
            assert!(
                c.matches,
                "{} (dep): predicted {} vs simulated {}",
                row.name, c.predicted.cpi, c.simulated.cpi
            );
            checked += 1;
        }
    }
    assert!(checked > 150, "swept both variants: {checked} checks");
}

#[test]
fn cross_arch_model_use_is_rejected() {
    // A model extracted on one architecture must refuse an engine built
    // for another — before any prediction can silently mix numbers.
    let m = model();
    assert_eq!(m.arch, "ampere", "extraction records the engine's arch");
    let turing = ampere_ubench::arch::get("turing").unwrap().config.into_small();
    let err = m.geometry_mismatch(&turing).expect("turing engine must be rejected");
    assert!(err.contains("turing"), "{err}");

    // The oracle-level startup check fires on the same mismatch…
    let o = LatencyOracle::with_engine(m.clone(), Engine::new(turing));
    assert!(o.config_mismatch().is_some());

    // …and same-arch use stays accepted (the baseline every other test
    // in this file relies on).
    assert!(m.geometry_mismatch(&AmpereConfig::small()).is_none());
}

#[test]
fn server_routes_requests_by_arch() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let mut c = Client::connect(handle.addr());

    // Explicit arch matching the hosted model answers normally.
    let v = c.roundtrip(r#"{"mode":"predict","instr":"add.u32","arch":"ampere","id":1}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");

    // An unhosted arch earns an error naming what is hosted.
    let v = c.roundtrip(r#"{"mode":"predict","instr":"add.u32","arch":"volta","id":2}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{v:?}");
    let err = v.get("error").and_then(Value::as_str).unwrap();
    assert!(err.contains("volta") && err.contains("ampere"), "{err}");
    assert_eq!(v.get("id").and_then(Value::as_u64), Some(2));

    // stats advertises the hosted architectures.
    let v = c.roundtrip(r#"{"mode":"stats"}"#);
    let archs = v.get("archs").and_then(Value::as_arr).unwrap();
    assert_eq!(archs.len(), 1);
    assert_eq!(archs[0].as_str(), Some("ampere"));

    handle.stop();
}

#[test]
fn prediction_cache_serves_repeats_without_recomputing() {
    let o = oracle();
    let src = alu::kernel_for(&registry::find("add.u32").unwrap(), false);
    let (p1, hit1) = o.predict_cached(&src).unwrap();
    let (p2, hit2) = o.predict_cached(&src).unwrap();
    assert!(!hit1 && hit2);
    assert_eq!(p1, p2);
    let s = o.stats();
    assert_eq!(s.predictions, 1);
    assert_eq!(s.cache.hits, 1);
}

// ---- loopback serving ------------------------------------------------

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Client { stream, reader }
    }

    fn roundtrip(&mut self, request: &str) -> Value {
        json::parse(&self.roundtrip_raw(request)).expect("response is JSON")
    }

    /// The raw response line exactly as the server wrote it (minus the
    /// line terminator) — for pinning bytes, not just values.
    fn roundtrip_raw(&mut self, request: &str) -> String {
        writeln!(self.stream, "{request}").expect("send");
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("receive");
        assert!(n > 0, "server closed the connection");
        line.trim().to_string()
    }
}

// ---- binary framing --------------------------------------------------

/// A binary frame around a handcrafted payload — tests drive the wire
/// format below the [`wire::encode_frame`] level.
fn raw_frame(payload: &[u8]) -> Vec<u8> {
    let mut f = vec![wire::MAGIC];
    f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    f.extend_from_slice(payload);
    f
}

struct BinClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl BinClient {
    fn connect(addr: std::net::SocketAddr) -> BinClient {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        BinClient { stream, reader }
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).expect("send");
    }

    fn read_value(&mut self) -> Value {
        match wire::read_frame(&mut self.reader).expect("read frame") {
            wire::FrameRead::Frame(payload) => {
                wire::decode_value(&payload).expect("decode response frame")
            }
            other => panic!("expected a response frame, got {other:?}"),
        }
    }

    fn roundtrip(&mut self, request: &Value) -> Value {
        self.send_raw(&wire::encode_frame(request));
        self.read_value()
    }
}

#[test]
fn loopback_server_concurrent_clients_deterministic_responses() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr();

    let expected_cpi = model().lookup("add.u32").expect("add.u32 in model").cpi;

    std::thread::scope(|s| {
        for client_id in 0..4u64 {
            s.spawn(move || {
                let mut c = Client::connect(addr);

                // ping
                let v = c.roundtrip(r#"{"mode":"ping"}"#);
                assert_eq!(v.get("pong"), Some(&Value::Bool(true)));

                // repeated single predictions: identical, deterministic
                for i in 0..5 {
                    let v = c.roundtrip(&format!(
                        r#"{{"mode":"predict","instr":"add.u32","id":{client_id}}}"#
                    ));
                    assert_eq!(
                        v.get("ok"),
                        Some(&Value::Bool(true)),
                        "client {client_id} iter {i}: {v:?}"
                    );
                    assert_eq!(v.get("cpi").and_then(Value::as_u64), Some(expected_cpi));
                    assert_eq!(v.get("id").and_then(Value::as_u64), Some(client_id));
                }

                // a batch: responses in request order, ids echoed.
                // (one line — the protocol is line-framed)
                let batch = [
                    r#"{"mode":"predict","instr":"add.u32","id":0}"#,
                    r#"{"mode":"predict","instr":"mul.lo.u32","id":1}"#,
                    r#"{"mode":"check","instr":"add.f64","id":2}"#,
                    r#"{"mode":"simulate","instr":"add.u32","id":3}"#,
                ];
                let v = c.roundtrip(&format!("[{}]", batch.join(",")));
                let arr = v.as_arr().expect("batch response is an array");
                assert_eq!(arr.len(), 4);
                for (i, r) in arr.iter().enumerate() {
                    assert_eq!(r.get("ok"), Some(&Value::Bool(true)), "slot {i}: {r:?}");
                    assert_eq!(r.get("id").and_then(Value::as_u64), Some(i as u64));
                }
                assert_eq!(arr[2].get("matches"), Some(&Value::Bool(true)));
                assert_eq!(
                    arr[3].get("mapping").and_then(Value::as_str),
                    Some("IADD"),
                    "simulate fell back to the live simulator pool"
                );

                // malformed input degrades to an error response, not a
                // dropped connection
                let v = c.roundtrip(r#"{"mode":"predict"}"#);
                assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
                let v = c.roundtrip("this is not json");
                assert_eq!(v.get("ok"), Some(&Value::Bool(false)));

                // and the connection still works afterwards
                let v = c.roundtrip(r#"{"mode":"stats"}"#);
                assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
                assert!(v.get("stats").is_some());
            });
        }
    });

    handle.stop();
}

/// Acceptance: both framings carry the same values — the decoded binary
/// response equals the parsed JSON response, and its canonical
/// re-serialization reproduces the JSON line byte for byte.  (`stats`
/// is excluded: its counters drift between the two captures.)
#[test]
fn binary_and_json_answers_are_byte_identical() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");

    let mul_src = alu::kernel_for(&registry::find("mul.lo.u32").unwrap(), false);
    let batch = Value::Arr(vec![
        Value::obj().set("mode", "predict").set("instr", "add.u32").set("id", 0_u64),
        Value::obj().set("mode", "predict").set("kernel", mul_src.as_str()).set("id", 1_u64),
        Value::obj().set("mode", "check").set("instr", "add.f64").set("id", 2_u64),
        Value::obj().set("mode", "simulate").set("instr", "add.u32").set("id", 3_u64),
        Value::obj().set("mode", "warp-drive").set("id", 4_u64),
        Value::obj().set("mode", "ping").set("id", 5_u64),
    ]);
    let line = json::to_string(&batch);

    // Prewarm over JSON so both captures below answer `cached:true`.
    let mut jc = Client::connect(handle.addr());
    jc.roundtrip(&line);
    let json_line = jc.roundtrip_raw(&line);

    let mut bc = BinClient::connect(handle.addr());
    let bin_value = bc.roundtrip(&batch);

    assert_eq!(
        bin_value,
        json::parse(&json_line).expect("json response parses"),
        "framings answered different values"
    );
    assert_eq!(
        json::to_string(&bin_value),
        json_line,
        "canonical serialization of the binary answer must reproduce the JSON bytes"
    );
    handle.stop();
}

/// Acceptance: `reload` swaps the model under live traffic — 4 clients
/// (2 JSON, 2 binary) stream predict batches across the swap with zero
/// dropped connections and no torn reads (every slot of a batch answers
/// from one model snapshot), and post-reload predictions come from the
/// new model.  A geometry-mismatched file is rejected with the
/// documented error and the connection survives.
#[test]
fn hot_reload_swaps_model_under_live_traffic() {
    const BATCH: usize = 4;
    let base = model().lookup("add.u32").expect("add.u32 in model").cpi;
    let new_cpi = base + 5;

    let mut bumped = model().clone();
    {
        let e = bumped.instructions.get_mut("add.u32").expect("add.u32 entry");
        e.cpi += 5;
        if let Some(d) = e.dep_cpi.as_mut() {
            *d += 5;
        }
    }
    let bumped_path = std::env::temp_dir().join("oracle_serving_reload_bumped.json");
    let bumped_path = bumped_path.to_str().unwrap().to_string();
    bumped.save(&bumped_path).unwrap();

    let mut wrong = model().clone();
    wrong.l1_bytes += 1;
    let wrong_path = std::env::temp_dir().join("oracle_serving_reload_wrong.json");
    let wrong_path = wrong_path.to_str().unwrap().to_string();
    wrong.save(&wrong_path).unwrap();

    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr();

    let batch = Value::Arr(
        (0..BATCH)
            .map(|i| {
                Value::obj().set("mode", "predict").set("instr", "add.u32").set("id", i as u64)
            })
            .collect(),
    );

    // One line/frame resolves against one model snapshot, so every slot
    // of a batch must report the same CPI even mid-swap.
    let check = |v: &Value| -> u64 {
        let arr = v.as_arr().expect("batch response is an array");
        assert_eq!(arr.len(), BATCH);
        let cpi = arr[0].get("cpi").and_then(Value::as_u64).expect("cpi");
        for (i, r) in arr.iter().enumerate() {
            assert_eq!(r.get("ok"), Some(&Value::Bool(true)), "slot {i}: {r:?}");
            assert_eq!(
                r.get("cpi").and_then(Value::as_u64),
                Some(cpi),
                "torn read: one batch answered from two models: {v:?}"
            );
        }
        assert!(cpi == base || cpi == new_cpi, "cpi {cpi} matches neither model");
        cpi
    };

    let total = AtomicU64::new(0);
    let fired = AtomicBool::new(false);

    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                let mut c = Client::connect(addr);
                let line = json::to_string(&batch);
                let deadline = Instant::now() + Duration::from_secs(30);
                loop {
                    let cpi = check(&c.roundtrip(&line));
                    total.fetch_add(1, Ordering::Relaxed);
                    if fired.load(Ordering::Acquire) && cpi == new_cpi {
                        break;
                    }
                    assert!(Instant::now() < deadline, "reload never became visible (json)");
                }
            });
            s.spawn(|| {
                let mut c = BinClient::connect(addr);
                let deadline = Instant::now() + Duration::from_secs(30);
                loop {
                    let cpi = check(&c.roundtrip(&batch));
                    total.fetch_add(1, Ordering::Relaxed);
                    if fired.load(Ordering::Acquire) && cpi == new_cpi {
                        break;
                    }
                    assert!(Instant::now() < deadline, "reload never became visible (binary)");
                }
            });
        }
        s.spawn(|| {
            // Fire the swap only once real traffic is in flight.
            while total.load(Ordering::Relaxed) < 12 {
                std::thread::sleep(Duration::from_millis(1));
            }
            let mut c = Client::connect(addr);
            let v = c.roundtrip(&format!(r#"{{"mode":"reload","model":"{bumped_path}"}}"#));
            fired.store(true, Ordering::Release);
            assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");
            assert_eq!(v.get("arch").and_then(Value::as_str), Some("ampere"));
            assert_eq!(v.get("reloads").and_then(Value::as_u64), Some(1));
        });
    });

    // A fresh connection predicts off the new model.
    let mut c = Client::connect(addr);
    let v = c.roundtrip(r#"{"mode":"predict","instr":"add.u32"}"#);
    assert_eq!(v.get("cpi").and_then(Value::as_u64), Some(new_cpi), "{v:?}");

    // Geometry mismatch: documented rejection, the connection survives,
    // and the bumped model keeps serving.
    let v = c.roundtrip(&format!(r#"{{"mode":"reload","model":"{wrong_path}"}}"#));
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
    assert!(
        v.get("error").and_then(Value::as_str).unwrap().contains("reload rejected"),
        "{v:?}"
    );
    let v = c.roundtrip(r#"{"mode":"ping"}"#);
    assert_eq!(v.get("pong"), Some(&Value::Bool(true)));
    let v = c.roundtrip(r#"{"mode":"predict","instr":"add.u32"}"#);
    assert_eq!(v.get("cpi").and_then(Value::as_u64), Some(new_cpi));

    // A missing file errors without touching the hosted model.
    let v = c.roundtrip(r#"{"mode":"reload","model":"/nonexistent/m.json"}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)));

    handle.stop();
    for p in [&bumped_path, &wrong_path] {
        let _ = std::fs::remove_file(p);
    }
}

/// Acceptance: the binary path matches the JSON path's input hardening —
/// one shared table of JSON-valid-but-invalid requests answers
/// identically over both framings with the connection intact, plus the
/// malformed inputs only one framing can express (unparseable text;
/// raw broken payloads, non-UTF-8 strings, oversized and desynced
/// frames).
#[test]
fn malformed_input_parity_across_wire_modes() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let mut jc = Client::connect(handle.addr());
    let mut bc = BinClient::connect(handle.addr());
    let ping = Value::obj().set("mode", "ping");

    let cases = [
        r#"{"mode":"predict"}"#,
        r#"{"mode":"warp-drive","instr":"add.u32"}"#,
        r#"{"instr":"add.u32","kernel":"x"}"#,
        r#"{"instr":"add.u32","typo":1}"#,
        r#"[1,2]"#,
        r#"42"#,
        r#"{"mode":true,"instr":"add.u32"}"#,
        r#"{"mode":"predict","instr":"add.u32","dependent":"yes"}"#,
        r#"{"kernel":42}"#,
        r#"{"mode":"reload","model":7}"#,
        r#"{"mode":"reload"}"#,
        r#"{"mode":"predict","instr":"add.u32","model":"m.json"}"#,
    ];
    for case in cases {
        let request = json::parse(case).expect("table cases are valid JSON");
        let jr = jc.roundtrip(case);
        let br = bc.roundtrip(&request);
        assert_eq!(jr, br, "framings disagree on {case}");
        match &jr {
            Value::Arr(slots) => {
                for r in slots {
                    assert_eq!(r.get("ok"), Some(&Value::Bool(false)), "{case}: {r:?}");
                }
            }
            v => assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{case}: {v:?}"),
        }
        // Neither connection dropped.
        assert_eq!(jc.roundtrip(r#"{"mode":"ping"}"#).get("pong"), Some(&Value::Bool(true)));
        assert_eq!(bc.roundtrip(&ping).get("pong"), Some(&Value::Bool(true)));
    }

    // JSON-only garbage: text no frame can carry still answers a line.
    for garbage in ["this is not json", r#"{"mode":"#, "}{"] {
        let v = jc.roundtrip(garbage);
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{garbage}");
        assert!(
            v.get("error").and_then(Value::as_str).unwrap().contains("bad json"),
            "{garbage}: {v:?}"
        );
        assert_eq!(jc.roundtrip(r#"{"mode":"ping"}"#).get("pong"), Some(&Value::Bool(true)));
    }

    // Binary-only: broken payloads answer an error frame and the
    // connection stays up.
    for (payload, what) in [
        (&[0x3f_u8][..], "unknown tag"),
        (&[0x06, 4, 0, 0, 0, b'a'][..], "truncated string"),
        (&[0x02, 0x00][..], "trailing byte after true"),
    ] {
        bc.send_raw(&raw_frame(payload));
        let v = bc.read_value();
        assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{what}");
        assert!(
            v.get("error").and_then(Value::as_str).unwrap().contains("bad frame payload"),
            "{what}: {v:?}"
        );
        assert_eq!(bc.roundtrip(&ping).get("pong"), Some(&Value::Bool(true)), "{what}");
    }

    // A non-UTF-8 kernel string decodes lossily and answers an ordinary
    // error — never a dropped connection.
    let push_raw_str = |out: &mut Vec<u8>, bytes: &[u8]| {
        out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(bytes);
    };
    let mut payload = vec![0x08_u8, 2, 0, 0, 0];
    push_raw_str(&mut payload, b"kernel");
    payload.push(0x06);
    push_raw_str(&mut payload, &[0xff, 0xfe]);
    push_raw_str(&mut payload, b"mode");
    payload.push(0x06);
    push_raw_str(&mut payload, b"predict");
    bc.send_raw(&raw_frame(&payload));
    let v = bc.read_value();
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{v:?}");
    assert_eq!(bc.roundtrip(&ping).get("pong"), Some(&Value::Bool(true)));

    // An oversized declared length answers once, then the connection
    // closes (the stream cannot re-frame).
    let mut oversized = vec![wire::MAGIC];
    oversized.extend_from_slice(&(wire::MAX_FRAME_BYTES + 1).to_le_bytes());
    bc.send_raw(&oversized);
    let v = bc.read_value();
    assert!(
        v.get("error").and_then(Value::as_str).unwrap().contains("exceeds"),
        "{v:?}"
    );
    match wire::read_frame(&mut bc.reader) {
        Ok(wire::FrameRead::Eof) | Err(_) => {}
        other => panic!("connection should close after an oversized header: {other:?}"),
    }

    // A desynchronized stream (bad magic mid-connection): one terminal
    // error frame, then close.
    let mut bc2 = BinClient::connect(handle.addr());
    assert_eq!(bc2.roundtrip(&ping).get("pong"), Some(&Value::Bool(true)));
    bc2.send_raw(&[0x00]);
    let v = bc2.read_value();
    assert!(
        v.get("error").and_then(Value::as_str).unwrap().contains("bad frame magic"),
        "{v:?}"
    );
    match wire::read_frame(&mut bc2.reader) {
        Ok(wire::FrameRead::Eof) | Err(_) => {}
        other => panic!("connection should close after desync: {other:?}"),
    }

    handle.stop();
}

/// Acceptance: the `gemm` wire mode serves the whole-kernel sweep —
/// every tile kernel simulated live on the serving engine and resolved
/// through the predictor's protocol replay, with per-row verdicts and
/// the aggregate `matches` bit all true.
#[test]
fn gemm_wire_mode_serves_the_sweep_with_exact_predictions() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let mut c = Client::connect(handle.addr());

    let v = c.roundtrip(r#"{"mode":"gemm","id":11}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");
    assert_eq!(v.get("mode").and_then(Value::as_str), Some("gemm"));
    assert_eq!(v.get("id").and_then(Value::as_u64), Some(11));
    assert_eq!(v.get("matches"), Some(&Value::Bool(true)), "{v:?}");
    let rows = v.get("rows").and_then(Value::as_arr).expect("rows array");
    assert!(rows.len() >= 5, "{} rows", rows.len());
    for r in rows {
        assert_eq!(r.get("match"), Some(&Value::Bool(true)), "{r:?}");
        let sim = r.get("sim_cycles").and_then(Value::as_u64).expect("sim_cycles");
        let pred = r.get("predicted_cycles").and_then(Value::as_u64).expect("predicted");
        assert_eq!(sim, pred, "{r:?}");
        assert!(sim > 0, "{r:?}");
    }
    // Both inner-loop flavours crossed the wire.
    let label = |r: &Value| r.get("label").and_then(Value::as_str).unwrap().to_string();
    assert!(rows.iter().any(|r| label(r).starts_with("fma[")));
    assert!(rows.iter().any(|r| label(r).starts_with("wmma[")));

    // A kernel payload on gemm is a validation error, connection intact.
    let v = c.roundtrip(r#"{"mode":"gemm","kernel":"x"}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{v:?}");
    assert_eq!(c.roundtrip(r#"{"mode":"ping"}"#).get("pong"), Some(&Value::Bool(true)));

    handle.stop();
}

/// Acceptance: prediction == simulation on looped kernels when the
/// model answers from disk — the save/load round-trip must preserve
/// everything the protocol replay consumes.
#[test]
fn saved_model_predicts_looped_kernels_exactly() {
    let path = std::env::temp_dir().join("oracle_serving_loop_model.json");
    let path = path.to_str().unwrap();
    model().save(path).unwrap();
    let loaded = LatencyModel::load(path).unwrap();
    let _ = std::fs::remove_file(path);

    let engine = Engine::new(AmpereConfig::small());
    let mut loops = 0u32;
    let mut seed = 0u64;
    while loops < 24 {
        assert!(seed < 4_000, "loop family too rare: {loops} in {seed} seeds");
        let case = ampere_ubench::fuzz::gen::generate_for_arch(
            seed,
            ampere_ubench::fuzz::gen::DEFAULT_SIZE,
            &engine.cfg().wmma_dtypes,
            &engine.cfg().nextgen,
        );
        seed += 1;
        if case.family != ampere_ubench::fuzz::gen::Family::Loop {
            continue;
        }
        let kernel = engine.compile(&case.src).unwrap();
        let mut sim = engine.simulator();
        let r = sim.run(&kernel.prog, &kernel.tp, &[0x100000]).unwrap();
        let sim_cycles =
            r.clock_reads[r.clock_reads.len() - 1] - r.clock_reads[0];
        let p = ampere_ubench::oracle::predict::predict_for(
            &loaded,
            &kernel.prog,
            &kernel.tp,
            Some(engine.cfg()),
        )
        .unwrap_or_else(|e| panic!("seed {}: {e}", case.seed));
        assert_eq!(
            p.cycles, sim_cycles,
            "seed {}: saved-model prediction diverged",
            case.seed
        );
        assert!(p.replayed_sass.is_some(), "seed {}: not replayed", case.seed);
        loops += 1;
    }
}

/// Acceptance: the 1-connection JSON-mode byte protocol is pinned —
/// existing clients parse these exact lines, so the sharded server must
/// reproduce them byte for byte (literal pins for the stable lines,
/// computed pins through the same canonical serializer for the
/// model-dependent ones).
#[test]
fn single_connection_json_protocol_is_pinned_byte_for_byte() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let mut c = Client::connect(handle.addr());

    assert_eq!(
        c.roundtrip_raw(r#"{"mode":"ping","id":"x"}"#),
        r#"{"id":"x","mode":"ping","ok":true,"pong":true}"#
    );
    assert_eq!(
        c.roundtrip_raw(r#"{"mode":"nope","id":9}"#),
        r#"{"error":"unknown mode \"nope\"","id":9,"ok":false}"#
    );
    assert_eq!(
        c.roundtrip_raw(r#"[{"mode":"ping","id":0},{"mode":"ping","id":1}]"#),
        concat!(
            r#"[{"id":0,"mode":"ping","ok":true,"pong":true},"#,
            r#"{"id":1,"mode":"ping","ok":true,"pong":true}]"#
        )
    );

    // Computed pins: the full predict/simulate key sets under canonical
    // sorted-key serialization, cold then warm.
    let o = oracle();
    let src = alu::kernel_for(&registry::find("add.u32").unwrap(), false);
    let (p, _) = o.predict_cached(&src).unwrap();
    let expect_predict = |id: u64, cached: bool| {
        json::to_string(
            &Value::obj()
                .set("ok", true)
                .set("mode", "predict")
                .set("id", id)
                .set("cpi", p.cpi)
                .set("cycles", p.cycles)
                .set("n", p.n)
                .set("unresolved", p.unresolved)
                .set("cached", cached),
        )
    };
    assert_eq!(
        c.roundtrip_raw(r#"{"mode":"predict","instr":"add.u32","id":1}"#),
        expect_predict(1, false)
    );
    assert_eq!(
        c.roundtrip_raw(r#"{"mode":"predict","instr":"add.u32","id":2}"#),
        expect_predict(2, true)
    );

    let s = o.simulate(&src).unwrap();
    let expect_sim = json::to_string(
        &Value::obj()
            .set("ok", true)
            .set("mode", "simulate")
            .set("id", 3_u64)
            .set("cpi", s.cpi)
            .set("delta", s.delta)
            .set("n", s.n)
            .set("mapping", s.mapping.as_str()),
    );
    assert_eq!(
        c.roundtrip_raw(r#"{"mode":"simulate","instr":"add.u32","id":3}"#),
        expect_sim
    );

    handle.stop();
}
