//! Integration: the latency-oracle subsystem end to end — model
//! extraction, JSON round-trip, static-vs-live self-consistency over
//! the full Table V registry, and the loopback TCP serving path with
//! concurrent clients.

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::microbench::{alu, registry};
use ampere_ubench::oracle::{LatencyModel, LatencyOracle, Server};
use ampere_ubench::util::json::{self, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, OnceLock};

/// One extracted model shared by every test in this binary (extraction
/// runs the full campaign once).
fn model() -> &'static LatencyModel {
    static MODEL: OnceLock<LatencyModel> = OnceLock::new();
    MODEL.get_or_init(|| {
        LatencyModel::extract(&Engine::new(AmpereConfig::small())).expect("extraction")
    })
}

fn oracle() -> LatencyOracle {
    LatencyOracle::with_engine(model().clone(), Engine::new(AmpereConfig::small()))
}

#[test]
fn extracted_model_round_trips_through_json() {
    let m = model();
    assert!(m.instructions.len() >= 95, "Table V-sized: {}", m.instructions.len());
    assert_eq!(m.memory.len(), 5, "five Table IV levels");
    assert_eq!(m.wmma.len(), 7, "seven Table III dtypes");
    assert_eq!(m.cold_start_cpi, vec![5, 3, 2, 2], "Table I curve");
    assert_eq!(m.clock_overhead, 2);

    let s = m.to_json_string();
    let back = LatencyModel::from_json_str(&s).expect("parse back");
    assert_eq!(&back, m, "serialize→parse is the identity");

    // And through a file, like `repro extract-model` writes it.
    let path = std::env::temp_dir().join("oracle_model_roundtrip.json");
    let path = path.to_str().unwrap();
    m.save(path).unwrap();
    assert_eq!(&LatencyModel::load(path).unwrap(), m);
    let _ = std::fs::remove_file(path);
}

#[test]
fn model_keys_are_unique_per_registry_row() {
    // Every Table V row must land its own entry — a key collision would
    // silently alias two instructions' CPIs.
    assert_eq!(
        model().instructions.len(),
        registry::table5().len(),
        "one model entry per registry row"
    );
}

/// Acceptance: for every Table V row, the static prediction from the
/// extracted model equals live `Engine` simulation of the same
/// microbenchmark kernel — same CPI, independent *and* dependent
/// variants.
#[test]
fn static_prediction_matches_live_sim_for_every_table5_row() {
    let o = oracle();
    let mut checked = 0;
    for row in registry::table5() {
        let src = alu::kernel_for(&row, false);
        let c = o.cross_check(&src).unwrap_or_else(|e| panic!("{}: {e}", row.name));
        assert!(
            c.matches,
            "{}: predicted {} vs simulated {}",
            row.name, c.predicted.cpi, c.simulated.cpi
        );
        assert_eq!(c.predicted.n, 3, "{}: three instances", row.name);
        checked += 1;

        if alu::can_chain(&row) {
            let dep_src = alu::kernel_for(&row, true);
            let c = o
                .cross_check(&dep_src)
                .unwrap_or_else(|e| panic!("{} (dep): {e}", row.name));
            assert!(
                c.matches,
                "{} (dep): predicted {} vs simulated {}",
                row.name, c.predicted.cpi, c.simulated.cpi
            );
            checked += 1;
        }
    }
    assert!(checked > 150, "swept both variants: {checked} checks");
}

#[test]
fn cross_arch_model_use_is_rejected() {
    // A model extracted on one architecture must refuse an engine built
    // for another — before any prediction can silently mix numbers.
    let m = model();
    assert_eq!(m.arch, "ampere", "extraction records the engine's arch");
    let turing = ampere_ubench::arch::get("turing").unwrap().config.into_small();
    let err = m.geometry_mismatch(&turing).expect("turing engine must be rejected");
    assert!(err.contains("turing"), "{err}");

    // The oracle-level startup check fires on the same mismatch…
    let o = LatencyOracle::with_engine(m.clone(), Engine::new(turing));
    assert!(o.config_mismatch().is_some());

    // …and same-arch use stays accepted (the baseline every other test
    // in this file relies on).
    assert!(m.geometry_mismatch(&AmpereConfig::small()).is_none());
}

#[test]
fn server_routes_requests_by_arch() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let mut c = Client::connect(handle.addr());

    // Explicit arch matching the hosted model answers normally.
    let v = c.roundtrip(r#"{"mode":"predict","instr":"add.u32","arch":"ampere","id":1}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(true)), "{v:?}");

    // An unhosted arch earns an error naming what is hosted.
    let v = c.roundtrip(r#"{"mode":"predict","instr":"add.u32","arch":"volta","id":2}"#);
    assert_eq!(v.get("ok"), Some(&Value::Bool(false)), "{v:?}");
    let err = v.get("error").and_then(Value::as_str).unwrap();
    assert!(err.contains("volta") && err.contains("ampere"), "{err}");
    assert_eq!(v.get("id").and_then(Value::as_u64), Some(2));

    // stats advertises the hosted architectures.
    let v = c.roundtrip(r#"{"mode":"stats"}"#);
    let archs = v.get("archs").and_then(Value::as_arr).unwrap();
    assert_eq!(archs.len(), 1);
    assert_eq!(archs[0].as_str(), Some("ampere"));

    handle.stop();
}

#[test]
fn prediction_cache_serves_repeats_without_recomputing() {
    let o = oracle();
    let src = alu::kernel_for(&registry::find("add.u32").unwrap(), false);
    let (p1, hit1) = o.predict_cached(&src).unwrap();
    let (p2, hit2) = o.predict_cached(&src).unwrap();
    assert!(!hit1 && hit2);
    assert_eq!(p1, p2);
    let s = o.stats();
    assert_eq!(s.predictions, 1);
    assert_eq!(s.cache.hits, 1);
}

// ---- loopback serving ------------------------------------------------

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Client { stream, reader }
    }

    fn roundtrip(&mut self, request: &str) -> Value {
        writeln!(self.stream, "{request}").expect("send");
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("receive");
        json::parse(line.trim()).expect("response is JSON")
    }
}

#[test]
fn loopback_server_concurrent_clients_deterministic_responses() {
    let server = Server::bind(Arc::new(oracle()), "127.0.0.1:0").expect("bind port 0");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr();

    let expected_cpi = model().lookup("add.u32").expect("add.u32 in model").cpi;

    std::thread::scope(|s| {
        for client_id in 0..4u64 {
            s.spawn(move || {
                let mut c = Client::connect(addr);

                // ping
                let v = c.roundtrip(r#"{"mode":"ping"}"#);
                assert_eq!(v.get("pong"), Some(&Value::Bool(true)));

                // repeated single predictions: identical, deterministic
                for i in 0..5 {
                    let v = c.roundtrip(&format!(
                        r#"{{"mode":"predict","instr":"add.u32","id":{client_id}}}"#
                    ));
                    assert_eq!(
                        v.get("ok"),
                        Some(&Value::Bool(true)),
                        "client {client_id} iter {i}: {v:?}"
                    );
                    assert_eq!(v.get("cpi").and_then(Value::as_u64), Some(expected_cpi));
                    assert_eq!(v.get("id").and_then(Value::as_u64), Some(client_id));
                }

                // a batch: responses in request order, ids echoed.
                // (one line — the protocol is line-framed)
                let batch = [
                    r#"{"mode":"predict","instr":"add.u32","id":0}"#,
                    r#"{"mode":"predict","instr":"mul.lo.u32","id":1}"#,
                    r#"{"mode":"check","instr":"add.f64","id":2}"#,
                    r#"{"mode":"simulate","instr":"add.u32","id":3}"#,
                ];
                let v = c.roundtrip(&format!("[{}]", batch.join(",")));
                let arr = v.as_arr().expect("batch response is an array");
                assert_eq!(arr.len(), 4);
                for (i, r) in arr.iter().enumerate() {
                    assert_eq!(r.get("ok"), Some(&Value::Bool(true)), "slot {i}: {r:?}");
                    assert_eq!(r.get("id").and_then(Value::as_u64), Some(i as u64));
                }
                assert_eq!(arr[2].get("matches"), Some(&Value::Bool(true)));
                assert_eq!(
                    arr[3].get("mapping").and_then(Value::as_str),
                    Some("IADD"),
                    "simulate fell back to the live simulator pool"
                );

                // malformed input degrades to an error response, not a
                // dropped connection
                let v = c.roundtrip(r#"{"mode":"predict"}"#);
                assert_eq!(v.get("ok"), Some(&Value::Bool(false)));
                let v = c.roundtrip("this is not json");
                assert_eq!(v.get("ok"), Some(&Value::Bool(false)));

                // and the connection still works afterwards
                let v = c.roundtrip(r#"{"mode":"stats"}"#);
                assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
                assert!(v.get("stats").is_some());
            });
        }
    });

    handle.stop();
}
