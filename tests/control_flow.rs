//! Control-flow property surface: the loop-aware execution stack pinned
//! end to end.
//!
//! * counted-loop cycle cost is linear in the trip count (warm trips
//!   all cost the same);
//! * predicated-off bodies charge exactly one issue slot per squashed
//!   instruction — nothing else;
//! * every branch-free Table V registry kernel predicts byte-identically
//!   through `predict` and the cfg-aware `predict_for` (the control-flow
//!   extension must not perturb the straight-line path);
//! * static prediction equals live simulation on 200 generated
//!   loop-family kernels — zero divergences.

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::fuzz::{diff, gen};
use ampere_ubench::microbench::{alu, gemm, registry};
use ampere_ubench::oracle::predict;

/// Simulated measured-window delta (closing minus opening clock read).
fn window_cycles(engine: &Engine, src: &str) -> u64 {
    let kernel = engine.compile(src).unwrap();
    let mut sim = engine.simulator();
    let r = sim.run(&kernel.prog, &kernel.tp, &[0x100000]).unwrap();
    assert!(r.clock_reads.len() >= 2, "kernel lost its clock brackets");
    r.clock_reads[r.clock_reads.len() - 1] - r.clock_reads[0]
}

/// Same window, through the static predictor's protocol replay.
fn predicted_cycles(engine: &Engine, src: &str) -> u64 {
    let kernel = engine.compile(src).unwrap();
    let model = gemm::replay_model(engine.cfg());
    let p = predict::predict_for(&model, &kernel.prog, &kernel.tp, Some(engine.cfg()))
        .unwrap();
    p.cycles
}

fn counted_loop(trips: u64) -> String {
    format!(
        ".visible .entry k(.param .u64 out) {{\n \
         .reg .b32 %r<40>;\n \
         .reg .b64 %rd<70>;\n \
         .reg .pred %p<4>;\n \
         mov.u64 %rd20, 0;\n \
         mov.u64 %rd60, %clock64;\n \
         $L:\n \
         add.u32 %r30, %r5, %r6;\n \
         add.u32 %r31, %r7, %r8;\n \
         add.u64 %rd20, %rd20, 1;\n \
         setp.lt.u64 %p1, %rd20, {trips};\n \
         @%p1 bra $L;\n \
         mov.u64 %rd61, %clock64;\n \
         ret;\n}}"
    )
}

#[test]
fn trip_count_scales_cycles_linearly() {
    let engine = Engine::new(AmpereConfig::a100());
    let c3 = window_cycles(&engine, &counted_loop(3));
    let c5 = window_cycles(&engine, &counted_loop(5));
    let c7 = window_cycles(&engine, &counted_loop(7));
    assert!(c3 < c5 && c5 < c7, "{c3} {c5} {c7}");
    // Cold-start effects are confined to trip one, which all three runs
    // share — so each extra pair of warm trips costs the same.
    assert_eq!(c5 - c3, c7 - c5, "warm trips must cost a constant");
    // And the static replay agrees with the live run at every count.
    for trips in [3, 5, 7] {
        let src = counted_loop(trips);
        assert_eq!(
            predicted_cycles(&engine, &src),
            window_cycles(&engine, &src),
            "trips={trips}"
        );
    }
}

fn squashed_body(guarded: usize) -> String {
    let body: Vec<String> = (0..guarded)
        .map(|i| format!("@%p1 add.u32 %r{}, %r5, %r6;", 30 + i))
        .collect();
    format!(
        ".visible .entry k(.param .u64 out) {{\n \
         .reg .b32 %r<40>;\n \
         .reg .b64 %rd<70>;\n \
         .reg .pred %p<4>;\n \
         mov.u64 %rd1, 0;\n \
         setp.lt.u64 %p1, %rd1, 0;\n \
         mov.u64 %rd60, %clock64;\n \
         {}\n \
         mov.u64 %rd61, %clock64;\n \
         ret;\n}}",
        body.join("\n ")
    )
}

#[test]
fn predicated_off_bodies_charge_issue_only() {
    let engine = Engine::new(AmpereConfig::a100());
    // %rd1 < 0 is always false: every guarded instruction squashes.  A
    // squashed instruction occupies one issue slot and nothing else, so
    // the window is the clock overhead plus one cycle per instruction.
    for guarded in [3usize, 5, 8] {
        let cycles = window_cycles(&engine, &squashed_body(guarded));
        assert_eq!(
            cycles,
            2 + guarded as u64,
            "{guarded} squashed instructions must cost issue slots only"
        );
    }
    // Flipping the guard on (0 < 1) makes the same body strictly dearer.
    let on = squashed_body(5).replace("setp.lt.u64 %p1, %rd1, 0;", "setp.lt.u64 %p1, %rd1, 1;");
    assert!(
        window_cycles(&engine, &on) > window_cycles(&engine, &squashed_body(5)),
        "executed body must out-cost the squashed one"
    );
}

#[test]
fn straight_line_registry_rows_unchanged_by_the_cfg_aware_predictor() {
    let engine = Engine::new(AmpereConfig::a100());
    let model = gemm::replay_model(engine.cfg());
    let rows = registry::table5();
    assert!(rows.len() >= 100, "registry shrank to {} rows", rows.len());
    for row in &rows {
        let src = alu::kernel_for(row, false);
        let kernel = engine.compile(&src).unwrap_or_else(|e| panic!("{}: {e}", row.name));
        let a = predict::predict(&model, &kernel.prog, &kernel.tp)
            .unwrap_or_else(|e| panic!("{}: {e}", row.name));
        let b = predict::predict_for(&model, &kernel.prog, &kernel.tp, Some(engine.cfg()))
            .unwrap_or_else(|e| panic!("{}: {e}", row.name));
        // Branch-free kernels must take the table-walk path in both
        // calls and agree field for field.
        assert_eq!(a.replayed_sass, None, "{}", row.name);
        assert_eq!(b.replayed_sass, None, "{}", row.name);
        assert_eq!(a.n, b.n, "{}", row.name);
        assert_eq!(a.cycles, b.cycles, "{}", row.name);
        assert_eq!(a.cpi, b.cpi, "{}", row.name);
        assert_eq!(a.bracketed, b.bracketed, "{}", row.name);
        assert_eq!(a.unresolved, b.unresolved, "{}", row.name);
        assert_eq!(a.per_instr.len(), b.per_instr.len(), "{}", row.name);
    }
}

#[test]
fn two_hundred_loop_kernels_predict_with_zero_divergences() {
    let engine = Engine::new(AmpereConfig::a100());
    let model = gemm::replay_model(engine.cfg());
    let mut checked = 0u32;
    let mut seed = 0u64;
    while checked < 200 {
        assert!(seed < 20_000, "loop family too rare: {checked} cases in {seed} seeds");
        let case = gen::generate_for_arch(
            seed,
            gen::DEFAULT_SIZE,
            &engine.cfg().wmma_dtypes,
            &engine.cfg().nextgen,
        );
        seed += 1;
        if case.family != gen::Family::Loop {
            continue;
        }
        let cpi = diff::run_case(&engine, &model, &case)
            .unwrap_or_else(|d| panic!("seed {}: {d:?}", case.seed));
        assert!(cpi >= 1, "seed {}", case.seed);
        checked += 1;
    }
}
