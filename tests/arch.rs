//! Integration: the architecture registry end to end — the `ampere`
//! preset's byte-identity with the historical config, WMMA capability
//! gating through campaign and fuzzing, quirk threading through the
//! engine's kernel cache, and the cross-architecture compare report.

use ampere_ubench::arch::{self, ArchSpec};
use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::microbench::{alu, registry, wmma};
use ampere_ubench::util::json::Value;
use ampere_ubench::{fuzz, harness, report};

/// Acceptance anchor: `repro --arch ampere <cmd>` must be the same run
/// as plain `repro <cmd>`.  The config is field-for-field identical,
/// and the rendered Table V (the full 132-row sweep) is byte-identical.
#[test]
fn ampere_arch_table5_is_byte_identical_to_legacy() {
    assert_eq!(arch::get("ampere").unwrap().config, AmpereConfig::a100());

    let legacy = Engine::new(AmpereConfig::small());
    let via_arch = Engine::new(arch::get("ampere").unwrap().config.into_small());
    let a = report::table5(&alu::run_table5_with(&legacy).unwrap());
    let b = report::table5(&alu::run_table5_with(&via_arch).unwrap());
    assert_eq!(a, b, "--arch ampere must not change a byte of Table V");
}

#[test]
fn volta_campaign_measures_only_its_wmma_dtypes() {
    let spec = arch::get("volta").unwrap();
    let engine = Engine::new(spec.config.clone().into_small());
    let t3 = wmma::run_table3_with(&engine).unwrap();
    let keys: Vec<&str> = t3.iter().map(|r| r.dtype_key).collect();
    assert_eq!(keys, vec!["f16_f16", "f16_f32"], "first-gen tensor cores are fp16-only");

    // Asking for an unsupported dtype is an error naming the capability
    // table, not a fabricated measurement.
    let err = wmma::measure_with(&engine, ampere_ubench::tensor::WmmaDtype::Tf32F32)
        .unwrap_err();
    assert!(err.contains("not supported"), "{err}");
    assert!(err.contains("volta"), "{err}");
}

#[test]
fn turing_engine_translates_under_its_own_quirks() {
    // The §V-A IADD3/IMAD.IADD alternation is an Ampere behaviour; a
    // Turing engine's kernel cache must translate dependent adds
    // without the FP-pipe borrow.
    let row = registry::table5()
        .into_iter()
        .find(|r| r.name == "add.u32")
        .unwrap();
    let dep_src = alu::kernel_for(&row, true);

    let ampere = Engine::new(arch::get("ampere").unwrap().config);
    let turing = Engine::new(arch::get("turing").unwrap().config);
    let a = ampere.compile(&dep_src).unwrap();
    let t = turing.compile(&dep_src).unwrap();
    assert!(
        a.tp.mappings().iter().any(|m| m == "IMAD.IADD"),
        "{:?}",
        a.tp.mappings()
    );
    assert!(
        t.tp.mappings().iter().all(|m| m != "IMAD.IADD"),
        "{:?}",
        t.tp.mappings()
    );
}

#[test]
fn fuzzing_respects_the_arch_capability_table() {
    // A Volta differential run must never generate a wmma case outside
    // the Volta capability table — and must still pass its three paths.
    let spec = arch::get("volta").unwrap();
    let engine = Engine::new(spec.config.clone().into_small());
    let model =
        ampere_ubench::oracle::LatencyModel::extract(&engine).expect("volta extraction");
    assert_eq!(model.arch, "volta");
    assert_eq!(model.wmma.len(), 2, "model only carries supported dtypes");

    let outcome = fuzz::diff::run(&engine, &model, 7, 40);
    assert_eq!(outcome.arch, "volta");
    assert!(
        outcome.failures.is_empty(),
        "volta differential run diverged: {}",
        outcome.render()
    );
}

/// Acceptance: a 200-case differential run on each new preset reports
/// zero divergences, with the nextgen family actually drawn — the
/// predictor, both simulator paths and the translator agree on
/// `cp.async`/TMA/wgmma/DSMEM kernels end to end.
#[test]
fn hopper_and_blackwell_fuzz_clean_including_the_nextgen_family() {
    for name in ["hopper", "blackwell"] {
        let spec = arch::get(name).unwrap();
        let engine = Engine::new(spec.config.clone().into_small());
        let model = ampere_ubench::oracle::LatencyModel::extract(&engine)
            .unwrap_or_else(|e| panic!("{name} extraction: {e}"));
        assert_eq!(model.nextgen.len(), 4, "{name} model carries every family");
        let outcome = fuzz::diff::run(&engine, &model, 11, 200);
        assert!(outcome.failures.is_empty(), "{name}: {}", outcome.render());
        assert!(
            outcome.family_counts.contains_key("nextgen"),
            "{name} stream never drew the nextgen family: {:?}",
            outcome.family_counts
        );
    }
}

/// Acceptance: `repro compare --arch ampere,turing --json` emits a
/// per-row delta table covering every Table V row.
#[test]
fn compare_json_covers_every_table5_row() {
    let specs = [arch::get("ampere").unwrap(), arch::get("turing").unwrap()];
    let runs: Vec<_> = specs
        .iter()
        .map(|s| {
            let engine = Engine::new(s.config.clone().into_small());
            let campaign = harness::run_campaign_with(&engine)
                .unwrap_or_else(|e| panic!("{}: {e}", s.name()));
            // The cross-arch IPC table: a small two-point sweep keeps
            // the test fast while exercising the alignment-by-name path.
            let sweep = ampere_ubench::microbench::throughput::run_sweep_with(&engine, &[1, 16])
                .unwrap_or_else(|e| panic!("{} sweep: {e}", s.name()));
            let nextgen = ampere_ubench::isa::run_families_with(&engine)
                .unwrap_or_else(|e| panic!("{} nextgen: {e}", s.name()));
            (campaign, sweep, nextgen)
        })
        .collect();
    let results: Vec<report::ArchResults<'_>> = specs
        .iter()
        .zip(&runs)
        .map(|(s, (c, t, ng))| report::ArchResults {
            arch: s.name(),
            table5: c.table5.as_slice(),
            table4: c.table4.as_slice(),
            table3: c.table3.as_slice(),
            throughput: t.as_slice(),
            nextgen: ng.as_slice(),
        })
        .collect();

    let rows = registry::table5().len();
    let v = report::compare_json(&results);
    assert_eq!(v.get("rows").and_then(Value::as_u64), Some(rows as u64));
    let t5 = v.get("table5").and_then(Value::as_arr).unwrap();
    assert_eq!(t5.len(), rows, "every Table V row compared");
    for row in t5 {
        let cpi = row.get("cpi").unwrap();
        assert!(cpi.get("ampere").and_then(Value::as_u64).is_some(), "{row:?}");
        assert!(cpi.get("turing").and_then(Value::as_u64).is_some(), "{row:?}");
        assert!(
            row.get("delta").and_then(|d| d.get("turing")).is_some(),
            "{row:?}"
        );
    }

    // The architectures measurably differ: at least one row has a
    // non-zero delta (Turing's fp64 port and memory latencies alone
    // guarantee it), and the fp64 rows are slower on Turing.
    let nonzero = t5
        .iter()
        .filter(|r| {
            r.get("delta")
                .and_then(|d| d.get("turing"))
                .and_then(Value::as_f64)
                .map(|d| d != 0.0)
                .unwrap_or(false)
        })
        .count();
    assert!(nonzero > 0, "ampere and turing measured identically?");
    let add_f64 = t5
        .iter()
        .find(|r| r.get("name").and_then(Value::as_str) == Some("add.f64"))
        .expect("add.f64 row");
    let a = add_f64.get("cpi").unwrap().get("ampere").unwrap().as_u64().unwrap();
    let t = add_f64.get("cpi").unwrap().get("turing").unwrap().as_u64().unwrap();
    assert!(t > a, "Turing's 1/32-rate fp64 must be slower: {t} vs {a}");

    // WMMA cross-table: bf16 measured on ampere, absent on turing.
    let wmma_rows = v.get("wmma").and_then(Value::as_arr).unwrap();
    let bf16 = wmma_rows
        .iter()
        .find(|r| r.get("dtype").and_then(Value::as_str) == Some("bf16_f32"))
        .unwrap();
    assert!(bf16.get("cycles").unwrap().get("ampere").unwrap().as_u64().is_some());
    assert_eq!(bf16.get("cycles").unwrap().get("turing"), Some(&Value::Null));

    // Cross-arch IPC deltas: every base sweep row appears, and Turing's
    // occupancy-16 fp64 port caps add.f64 peak IPC below Ampere's.
    let tp = v.get("throughput").and_then(Value::as_arr).unwrap();
    assert_eq!(
        tp.len(),
        registry::table5().len() + specs[0].config.wmma_dtypes.len(),
        "one IPC row per registry row + ampere wmma dtype"
    );
    let f64_row = tp
        .iter()
        .find(|r| r.get("name").and_then(Value::as_str) == Some("add.f64"))
        .expect("add.f64 IPC row");
    let peak = f64_row.get("peak_ipc_milli").unwrap();
    let a = peak.get("ampere").unwrap().as_u64().unwrap();
    let t = peak.get("turing").unwrap().as_u64().unwrap();
    assert!(
        t < a,
        "Turing's 1/32-rate fp64 port must cap peak IPC: {t} vs {a}"
    );
    assert!(
        f64_row.get("delta_milli").and_then(|d| d.get("turing")).is_some(),
        "{f64_row:?}"
    );
    // bf16 WMMA exists on ampere only → null on turing, by name.
    let bf16_tp = tp
        .iter()
        .find(|r| r.get("name").and_then(Value::as_str) == Some("bf16_f32"))
        .unwrap();
    assert_eq!(
        bf16_tp.get("peak_ipc_milli").unwrap().get("turing"),
        Some(&Value::Null)
    );

    // Next-gen families: ampere has cp.async numbers, turing answers
    // null for every family — the rows stay so the table is rectangular.
    let ng = v.get("nextgen").and_then(Value::as_arr).unwrap();
    assert_eq!(ng.len(), 4, "one row per registry family");
    let cp = ng
        .iter()
        .find(|r| r.get("family").and_then(Value::as_str) == Some("cp_async"))
        .unwrap();
    assert!(
        cp.get("completion").unwrap().get("ampere").unwrap().as_u64().is_some(),
        "{cp:?}"
    );
    assert_eq!(cp.get("completion").unwrap().get("turing"), Some(&Value::Null));
    assert_eq!(
        cp.get("sass").unwrap().get("ampere").and_then(Value::as_str),
        Some("LDGSTS.E.128")
    );

    // And the printed form renders every row plus the unsupported
    // marker.
    let printed = report::compare(&results);
    assert!(printed.contains("add.f64"), "{printed}");
    assert!(printed.contains("132 rows") || printed.contains(&format!("{rows} rows")));
    assert!(printed.contains('-'), "unsupported dtypes print as '-'");
    assert!(printed.contains("Cross-arch next-gen ISA"), "{printed}");
    assert!(printed.contains("cp.async.ca.shared.global"), "{printed}");
}

#[test]
fn arch_spec_round_trips_and_diffs_through_the_cli_surface() {
    // `arch show --json` output is a loadable custom spec.
    let spec = ArchSpec::volta();
    let reloaded = ArchSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(reloaded, spec);

    // `arch diff volta ampere` surfaces the WMMA dtype gap.
    let table = arch::diff_table(&ArchSpec::volta(), &ArchSpec::ampere());
    for needle in ["wmma.bf16_f32", "wmma.tf32_f32", "sm_count"] {
        assert!(table.contains(needle), "{needle} missing:\n{table}");
    }
}

/// Satellite: the CLI surface covers the next-gen section — `arch diff
/// ampere hopper` flattens the family table, `arch show --json` output
/// for the new presets is a loadable custom spec, and partial specs are
/// still rejected with the missing field named.
#[test]
fn arch_cli_surface_carries_the_nextgen_section() {
    for name in ["hopper", "blackwell"] {
        let spec = arch::get(name).unwrap();
        let reloaded = ArchSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(reloaded, spec, "{name} show --json must round-trip");
    }

    // The flattened diff names the family fields ampere lacks ('-' on
    // the a side) and the one it shares with a different number.
    let table = arch::diff_table(&ArchSpec::ampere(), &ArchSpec::hopper());
    for needle in [
        "nextgen.tma.latency",
        "nextgen.tma.occupancy",
        "nextgen.wgmma.occupancy",
        "nextgen.dsmem.latency",
        "nextgen.cp_async.latency",
    ] {
        assert!(table.contains(needle), "{needle} missing:\n{table}");
    }

    // A spec stripped of a required field is rejected, not defaulted.
    let broken = ArchSpec::hopper()
        .to_json_string()
        .replace("\"sm_count\"", "\"sm_count_gone\"");
    let err = ArchSpec::from_json_str(&broken).unwrap_err();
    assert!(err.contains("sm_count"), "{err}");
}
