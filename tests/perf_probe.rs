//! §Perf probe: where does a Table V measurement spend its time?
//! Run: cargo test --release --test perf_probe -- --nocapture --ignored
use ampere_ubench::config::AmpereConfig;
use ampere_ubench::microbench::{alu, registry};
use ampere_ubench::ptx::parse_program;
use ampere_ubench::sim::Simulator;
use ampere_ubench::translate::translate_program;
use std::time::Instant;

#[test]
#[ignore]
fn phase_breakdown() {
    let cfg = AmpereConfig::a100();
    let rows = registry::table5();
    let srcs: Vec<String> = rows.iter().map(|r| alu::kernel_for(r, false)).collect();
    let n = srcs.len() as f64;

    let t = Instant::now();
    let progs: Vec<_> = srcs.iter().map(|s| parse_program(s).unwrap()).collect();
    println!("parse:     {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    let t = Instant::now();
    let tps: Vec<_> = progs.iter().map(|p| translate_program(p).unwrap()).collect();
    println!("translate: {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    let t = Instant::now();
    let mut sims: Vec<_> = (0..progs.len()).map(|_| Simulator::new(cfg.clone())).collect();
    println!("sim-new:   {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    let t = Instant::now();
    for ((p, tp), sim) in progs.iter().zip(&tps).zip(&mut sims) {
        sim.run(p, tp, &[0x100000]).unwrap();
    }
    println!("sim-run:   {:>8.1} µs/kernel", t.elapsed().as_micros() as f64 / n);

    // raw simulated-instruction throughput on a long loop
    let src = format!(
        ".visible .entry k() {{ {} mov.u64 %rd1, 0;\n$L:\n add.u64 %rd1, %rd1, 1;\n \
         add.u32 %r1, %r2, 1;\n add.u32 %r3, %r4, 1;\n add.u32 %r5, %r6, 1;\n \
         setp.lt.u64 %p1, %rd1, 1000000;\n @%p1 bra $L;\n ret; }}",
        ampere_ubench::microbench::REG_DECLS
    );
    let p = parse_program(&src).unwrap();
    let tp = translate_program(&p).unwrap();
    let mut sim = Simulator::new(cfg.clone());
    sim.trace = ampere_ubench::sass::TraceRecorder::disabled();
    let t = Instant::now();
    let r = sim.run(&p, &tp, &[]).unwrap();
    let secs = t.elapsed().as_secs_f64();
    println!(
        "loop:      {:.1} M SASS instr/s ({} instrs in {:.2}s)",
        r.sass_instructions as f64 / secs / 1e6,
        r.sass_instructions,
        secs
    );
}
