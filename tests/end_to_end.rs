//! End-to-end: the full campaign against the paper's published numbers
//! (the integration-level version of DESIGN.md §6's experiment index).

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::harness;
use ampere_ubench::microbench::memory::Level;
use ampere_ubench::microbench::MatchGrade;

fn cfg() -> AmpereConfig {
    // scaled caches: identical latencies, faster warm loops
    AmpereConfig::small()
}

#[test]
fn campaign_reproduces_every_table() {
    let r = harness::run_campaign_blocking(cfg()).unwrap();

    // Table I — exact: 5, 3, 2, 2.
    assert_eq!(
        r.table1.iter().map(|a| a.cpi).collect::<Vec<_>>(),
        vec![5, 3, 2, 2]
    );

    // Table II — exact for all five rows, both columns.
    for d in &r.table2 {
        assert_eq!((d.dep_cpi, d.indep_cpi), (d.paper_dep, d.paper_indep), "{}", d.name);
    }

    // Table III — exact latency + SASS decomposition for all 7 dtypes,
    // throughput within 5% of the paper's measured column.
    for w in &r.table3 {
        assert_eq!(w.cycles, w.paper_cycles, "{}", w.dtype_key);
        assert_eq!(w.sass, w.paper_sass, "{}", w.dtype_key);
        let rel = (w.throughput.measured_tops - w.paper_measured_tops).abs()
            / w.paper_measured_tops;
        assert!(rel < 0.05, "{}: throughput {rel}", w.dtype_key);
    }

    // Table IV — ordering + ≤6% per-row error; shared exact.
    let get = |l: Level| r.table4.iter().find(|m| m.level == l).unwrap().cpi;
    assert!(get(Level::Global) > get(Level::L2));
    assert!(get(Level::L2) > get(Level::L1));
    assert!(get(Level::L1) > get(Level::SharedLoad));
    assert_eq!(get(Level::SharedLoad), 23);
    assert_eq!(get(Level::SharedStore), 19);

    // Table V — ≥60% exact, ≥95% exact-or-close across ~114 rows.
    let s = r.summary();
    assert!(
        s.table5_exact * 10 >= s.table5_rows * 6,
        "{} exact of {}",
        s.table5_exact,
        s.table5_rows
    );
    assert!(
        (s.table5_exact + s.table5_close) * 20 >= s.table5_rows * 19,
        "{} exact + {} close of {}",
        s.table5_exact,
        s.table5_close,
        s.table5_rows
    );

    // Fig. 4 — exact: 13 vs 2.
    assert_eq!(r.fig4.cpi_32bit, 13);
    assert_eq!(r.fig4.cpi_64bit, 2);

    // Insights.
    assert_eq!(r.insight1.mad_mapping, "FFMA");
    for p in &r.insight2 {
        assert_eq!(p.differs, p.paper_expects_difference, "{}", p.base);
    }
    for i in &r.insight3 {
        assert_eq!(i.mov_init_mapping, "IMAD.MOV.U32", "{}", i.op);
        assert!(i.add_init_mapping.starts_with("FADD"), "{}", i.op);
    }
}

#[test]
fn table5_mapping_strings_mostly_verbatim() {
    let r = harness::run_campaign_blocking(cfg()).unwrap();
    let mismatched: Vec<_> = r
        .table5
        .iter()
        .filter(|row| !row.mapping_matches)
        .map(|row| row.name.clone())
        .collect();
    assert!(
        mismatched.len() * 10 <= r.table5.len(),
        "mapping mismatches: {mismatched:?}"
    );
}

#[test]
fn grades_never_regress_below_published_baseline() {
    // The calibration baseline recorded in EXPERIMENTS.md — any code
    // change that degrades it should fail here.
    let r = harness::run_campaign_blocking(cfg()).unwrap();
    let s = r.summary();
    assert!(s.table5_exact >= 70, "exact dropped to {}", s.table5_exact);
    let off = r
        .table5
        .iter()
        .filter(|x| x.cycles_grade == MatchGrade::Off)
        .count();
    assert!(off <= 2, "off rows grew to {off}");
}
