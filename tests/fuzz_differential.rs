//! Differential fuzzing over generated kernels — the integration-level
//! twin of the `repro fuzz` CLI path.
//!
//! Covers the pool-reset invariant on *fuzz-generated* kernels (the
//! engine's reset-byte-identical property extended beyond registry
//! rows), translator determinism across independent compiles, and
//! predictor self-consistency on the predictor-exact families.
//!
//! Depth scales with `FUZZ_CASES` (see `util::prng::check`).

use ampere_ubench::config::AmpereConfig;
use ampere_ubench::engine::Engine;
use ampere_ubench::fuzz::{diff, gen};
use ampere_ubench::oracle::LatencyModel;
use ampere_ubench::ptx::parse_program;
use ampere_ubench::translate::translate_program;
use ampere_ubench::util::prng::check;
use std::sync::OnceLock;

const PARAMS: &[u64] = &[0x100000];

/// One extracted model shared by every test in this binary (extraction
/// runs the full campaign once).
fn model() -> &'static LatencyModel {
    static MODEL: OnceLock<LatencyModel> = OnceLock::new();
    MODEL.get_or_init(|| {
        LatencyModel::extract(&Engine::new(AmpereConfig::small())).expect("extraction")
    })
}

#[test]
fn prop_generated_kernels_always_compile_and_run() {
    let cfg = AmpereConfig::small();
    check("fuzz-gen-valid", 40, |rng| {
        let seed = rng.next_u64();
        // Every error names the *generation* seed, so the failing kernel
        // regenerates exactly via `repro fuzz --seed <seed> --cases 1`.
        let ctx = |what: &str, case: &gen::FuzzCase| {
            format!(
                "{what} [{}] (replay: repro fuzz --seed {seed} --cases 1)",
                case.label
            )
        };
        let case = gen::generate(seed, gen::DEFAULT_SIZE);
        let again = gen::generate(seed, gen::DEFAULT_SIZE);
        if case.src != again.src {
            return Err(ctx("generation is nondeterministic", &case));
        }
        let prog = parse_program(&case.src)
            .map_err(|e| ctx(&format!("parse: {e}"), &case))?;
        let tp = translate_program(&prog)
            .map_err(|e| ctx(&format!("translate: {e}"), &case))?;
        prog.validate()
            .map_err(|e| ctx(&format!("validate: {e}"), &case))?;
        let mut sim = ampere_ubench::sim::Simulator::new(cfg.clone());
        let r = sim
            .run(&prog, &tp, PARAMS)
            .map_err(|e| ctx(&format!("run: {e}"), &case))?;
        if r.clock_reads.len() < 2 {
            return Err(ctx("lost its clock brackets", &case));
        }
        Ok(())
    });
}

#[test]
fn prop_pool_reset_matches_fresh_for_generated_kernels() {
    // The engine invariant, extended per the fuzz charter: after
    // running ANY generated kernel, a reset pooled simulator must match
    // a fresh one bit for bit — not just on registry rows.
    let engine = Engine::with_workers(AmpereConfig::small(), 1);
    check("fuzz-pool-reset", 25, |rng| {
        let seed = rng.next_u64();
        let case = gen::generate(seed, gen::DEFAULT_SIZE);
        let ctx = |what: String| {
            format!(
                "{what} [{}] (replay: repro fuzz --seed {seed} --cases 1)",
                case.label
            )
        };
        let k = engine
            .compile(&case.src)
            .map_err(|e| ctx(format!("compile: {e}")))?;
        // Dirty a pooled instance with the kernel (reset on drop)…
        {
            let mut sim = engine.simulator();
            sim.run(&k.prog, &k.tp, PARAMS)
                .map_err(|e| ctx(format!("dirtying run: {e}")))?;
        }
        // …then the recycled instance must equal a fresh build exactly.
        let recycled = {
            let mut sim = engine.simulator();
            sim.run(&k.prog, &k.tp, PARAMS)
                .map_err(|e| ctx(format!("recycled run: {e}")))?
        };
        let fresh = engine
            .fresh_simulator()
            .run(&k.prog, &k.tp, PARAMS)
            .map_err(|e| ctx(format!("fresh run: {e}")))?;
        if recycled != fresh {
            return Err(ctx("recycled != fresh".to_string()));
        }
        Ok(())
    });
}

#[test]
fn differential_run_reports_zero_divergences() {
    let engine = Engine::new(AmpereConfig::small());
    let cases = ampere_ubench::util::prng::fuzz_cases(60);
    let outcome = diff::run(&engine, model(), 1, cases);
    assert_eq!(outcome.cases, cases);
    assert!(outcome.failures.is_empty(), "{}", outcome.render());
    assert!(
        outcome.family_counts.len() >= 4,
        "family spread too thin: {:?}",
        outcome.family_counts
    );
    // The JSON report carries the pass verdict the CI artifact shows.
    assert_eq!(
        outcome.to_json().get("pass").and_then(|v| v.as_bool()),
        Some(true)
    );
}

#[test]
fn predictor_path_is_live_not_vacuous() {
    // Corrupt one model entry: a predictor-exact case measuring that
    // instruction must now classify as PredictorMismatch — proving the
    // third differential path actually gates.
    let engine = Engine::new(AmpereConfig::small());
    let mut bad = model().clone();
    {
        let e = bad.instructions.get_mut("add.u32").expect("model has add.u32");
        e.cpi = 40;
        e.dep_cpi = Some(41);
    }
    let mut hit = false;
    for seed in 0..5000u64 {
        let case = gen::generate(seed, gen::DEFAULT_SIZE);
        if case.predict_exact && case.label.starts_with("add.u32") {
            let d = diff::run_case(&engine, &bad, &case).expect_err("must diverge");
            assert_eq!(d.kind, diff::DivergenceKind::PredictorMismatch, "{d:?}");
            // And the same case against the honest model passes.
            assert!(diff::run_case(&engine, model(), &case).is_ok());
            hit = true;
            break;
        }
    }
    assert!(hit, "no add.u32 alu case found in 5000 seeds");
}

#[test]
fn reproducer_dump_round_trips() {
    // A forced failure dumps a .ptx that replays and a JSON report that
    // names the divergence and the rerun command.
    let engine = Engine::new(AmpereConfig::small());
    let mut bad = model().clone();
    bad.instructions.get_mut("add.u32").expect("add.u32").cpi = 40;
    let mut target = None;
    for seed in 0..5000u64 {
        let c = gen::generate(seed, gen::DEFAULT_SIZE);
        if c.predict_exact && c.label == "add.u32" {
            target = Some((seed, c));
            break;
        }
    }
    let (seed, case) = target.expect("an add.u32 alu case");
    let divergence = diff::run_case(&engine, &bad, &case).unwrap_err();
    let failure = diff::Failure {
        index: 0,
        case_seed: seed,
        original_len: case.src.len(),
        case,
        divergence,
    };
    let dir = std::env::temp_dir().join("fuzz_repro_test");
    std::fs::create_dir_all(&dir).unwrap();
    let dir = dir.to_str().unwrap();
    let (ptx, json_path) = diff::dump_reproducer(dir, &failure).unwrap();
    let src = std::fs::read_to_string(&ptx).unwrap();
    assert!(parse_program(&src).is_ok(), "reproducer must replay");
    let report =
        ampere_ubench::util::json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
    assert_eq!(
        report.get("kind").and_then(|v| v.as_str()),
        Some("predictor-mismatch")
    );
    assert_eq!(
        report.get("rerun").and_then(|v| v.as_str()),
        Some(format!("repro fuzz --seed {seed} --cases 1").as_str())
    );
    let _ = std::fs::remove_file(&ptx);
    let _ = std::fs::remove_file(&json_path);
}
