//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so the repo vendors the
//! small API subset it actually uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//!
//! Semantics match upstream where it matters here:
//! * `Error` carries a chain of messages (outermost context first);
//! * `Display` prints the outermost message, `{:#}` (alternate) prints
//!   the whole chain joined by `": "`;
//! * `?` converts any `std::error::Error + Send + Sync + 'static` via a
//!   blanket `From` (which is why `Error` itself deliberately does *not*
//!   implement `std::error::Error`).

use std::fmt;

/// A dynamically typed error: an ordered chain of messages, outermost
/// context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
            None => write!(f, "(empty error)"),
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, upstream-style.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest — run `make artifacts`".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest — run `make artifacts`");
        let alt = format!("{e:#}");
        assert!(alt.contains("make artifacts") && alt.contains("missing"), "{alt}");
    }

    #[test]
    fn macros_and_msg() {
        let e = anyhow!("bad {} {}", "thing", 3);
        assert_eq!(format!("{e}"), "bad thing 3");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 1");
        let e = Error::msg(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
