//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The real crate links the XLA CPU client; this build environment has
//! no network and no vendored native library, so the stub presents the
//! same API surface and fails at the single entry point that would need
//! the native backend: [`PjRtClient::cpu`].  Everything downstream
//! (`runtime::Oracle`, the oracle integration tests) already treats an
//! unavailable backend as "skip", so the rest of the suite is unaffected.

use std::fmt;

/// Error type matching the real crate's role; implements
/// `std::error::Error` so `?` converts into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT native backend is not vendored in this build environment (offline stub)";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types the literal API is used with.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host literal (opaque in the stub — no backend can ever produce one
/// with data in it).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// The real crate brings up the XLA CPU client here; the stub
    /// reports the backend as unavailable so callers degrade to their
    /// "skip oracle validation" paths.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn literal_construction_is_pure() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err(), "no backend to realise data");
    }
}
